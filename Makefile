# Tier-1 verification + hot-path smoke. `make verify` is what CI runs.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test bench-smoke bench resume-smoke sweep-smoke chaos-smoke bench-sweep bench-sweep-smoke

verify: test bench-smoke

test:
	$(PY) -m pytest -x -q

# 20-step engine smoke: catches hot-path perf regressions loudly (the run
# itself failing — compile error, shape drift, engine/loop divergence — is
# the signal; thresholds live in the full bench's JSON history)
bench-smoke:
	$(PY) -m benchmarks.bench_engine --steps 20 --windows 1 \
	    --out results/BENCH_engine_smoke.json

bench:
	$(PY) -m benchmarks.bench_engine

# 20-step preemption drill: checkpoint at 10, resume, final loss must be
# bitwise-equal to the uninterrupted run (exact-resume guarantee)
resume-smoke:
	$(PY) scripts/resume_smoke.py

# scaling-law sweep drill: reduced (N x M) grid with a simulated mid-sweep
# kill — rerun must skip ledger-complete cells, resume the rest from their
# checkpoints, then fit the ledger (results/SWEEP_smoke.jsonl + FITS_smoke.json)
sweep-smoke:
	$(PY) scripts/sweep_smoke.py

# deterministic chaos drill: replica crash + rejoin under a fault schedule,
# checksum-detectable checkpoint corruption with fallback to the last
# intact one, transient I/O faults absorbed by retry, resume bitwise-equal
# to the uninterrupted run of the same schedule; plus sweep-cell failure
# containment (error ledger records keep the sweep alive)
chaos-smoke:
	$(PY) scripts/chaos_smoke.py

# sweep-throughput bench: sequential vs shared-executable vs cell-stacked
# on the 6-cell lr/seed grid; --check asserts stacked >= sequential
# cells/sec, executable reuse, and bitwise-identical ledgers
bench-sweep-smoke:
	$(PY) -m benchmarks.bench_sweep --grids smoke-stack --check \
	    --out results/BENCH_sweep_smoke.json

bench-sweep:
	$(PY) -m benchmarks.bench_sweep --check --warm-cache-grid smoke-stack
