"""Compiled H-step superstep engine: one executable per outer round.

The paper's wall-clock argument (and the ROADMAP's "fast as the hardware
allows" north star) makes the inner loop the hot path: DiLoCo syncs every H
steps precisely so that the other H-1 steps run at hardware speed.  A
per-step Python loop gives that speed back — one dispatch per inner step, a
host-built batch per step, a full state copy per call (no donation), and a
blocking ``float(metrics["loss"])`` host sync per step.

``SuperstepEngine`` removes all of it.  One jitted, donated executable runs
an entire outer round:

* ``lax.scan`` over the H inner steps;
* on-device batch generation — for ``SyntheticLM`` the step counter is
  folded into the PRNG key *inside* the scan body (bitwise-identical
  batches to the host path, zero host->device traffic); file-backed
  sources get a double-buffered ``device_put`` prefetcher instead;
* the outer sync in the same executable — full, int8-compressed (error
  feedback carried in the donated state), or fragment-wise streaming
  (``lax.cond`` on the static fragment schedule inside the scan body, so
  mid-round fragment syncs land on exactly the step the per-step loop
  would run them);
* stacked ``(H, ...)`` metrics returned to the host — ONE host sync per
  outer round instead of one per step.

Donation caveat: the state passed to ``run_round``/``run`` is CONSUMED
(XLA aliases its buffers for the update).  Rebind ``state = engine.run_*``
and never touch the old reference.
"""
from __future__ import annotations

import concurrent.futures
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import streaming
from repro.data import SyntheticLM


def device_batch_fn(data: SyntheticLM, num_replicas: int, batch_seqs: int) -> Callable:
    """Traceable ``step -> global batch``, bitwise-equal to
    ``data.global_batch(step, num_replicas, batch_seqs)``.

    The step counter (a traced int32 inside the superstep's scan) is folded
    into the PRNG key exactly as the host path folds the Python int, and the
    per-replica generator runs under ``vmap`` — so batches are generated on
    device, inside the compiled round, with no host involvement.
    """
    M = num_replicas

    def batch_at(step: jax.Array) -> dict:
        key = jax.random.fold_in(data._root, step)

        def one(m):
            k = jax.random.fold_in(key, m + M * 7919)
            return data._gen(k, batch_seqs)

        toks = jax.vmap(one)(jnp.arange(M))  # (M, b, L+1)
        return {
            "tokens": toks[..., :-1].astype(jnp.int32),
            "labels": toks[..., 1:].astype(jnp.int32),
        }

    return batch_at


class RoundPrefetcher:
    """Double-buffered host->device batch pipeline for file-backed sources.

    While round r executes on device, a worker thread assembles round r+1's
    stacked ``(H, M, b, L)`` batch and ``device_put``s it, so in steady
    state the engine never blocks on host-side batch assembly or transfer.
    """

    def __init__(self, data: Any, num_replicas: int, batch_seqs: int):
        self._data = data
        self._m = num_replicas
        self._bs = batch_seqs
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Dict[Tuple[int, int], concurrent.futures.Future] = {}

    def _build(self, start: int, length: int):
        rounds = [
            self._data.global_batch(start + i, self._m, self._bs)
            for i in range(length)
        ]
        stacked = jax.tree.map(
            lambda *xs: np.stack([np.asarray(x) for x in xs]), *rounds
        )
        return jax.device_put(stacked)

    def schedule(self, start: int, length: int) -> None:
        key = (start, length)
        if key not in self._pending:
            self._pending[key] = self._pool.submit(self._build, start, length)

    def get(self, start: int, length: int, next_length: Optional[int] = None):
        """Return the (start, length) round; prefetch the following round of
        ``next_length`` steps (default: same length; 0 = end of training,
        prefetch nothing).  Mis-predicted pending rounds are discarded so
        stale batches don't pin device memory."""
        fut = self._pending.pop((start, length), None)
        for stale in list(self._pending):
            self._pending.pop(stale).cancel()
        xs = fut.result() if fut is not None else self._build(start, length)
        next_length = length if next_length is None else next_length
        if next_length > 0:
            self.schedule(start + length, next_length)
        return xs

    def close(self) -> None:
        """Drop any pending readahead and stop the worker.  Call after the
        last round when driving ``run_round`` directly without the
        ``next_length=0`` end hint, so the final speculative batch doesn't
        stay pinned on device for the engine's lifetime."""
        for key in list(self._pending):
            self._pending.pop(key).cancel()
        self._pool.shutdown(wait=False)


class SuperstepEngine:
    """Runs training one compiled, donated outer round per dispatch.

    ``chunk`` (default ``dcfg.sync_every``) is the scan length; rounds that
    end on an H boundary include the outer sync in the same executable.
    """

    def __init__(
        self,
        trainer,
        data,
        batch_seqs: int,
        *,
        chunk: int = 0,
        donate: bool = True,
        device_datagen: Optional[bool] = None,
        unroll: int = 1,
    ):
        dcfg = trainer.dcfg
        if dcfg.streaming_fragments > 0 and dcfg.compression != "none":
            raise ValueError("streaming fragments do not support compression")
        if chunk and not dcfg.data_parallel and chunk != dcfg.sync_every:
            raise ValueError(
                f"chunk ({chunk}) must equal sync_every ({dcfg.sync_every}) "
                "for DiLoCo; a free chunk length is only meaningful for DP"
            )
        self.trainer = trainer
        self.data = data
        self.batch_seqs = batch_seqs
        self.chunk = chunk or dcfg.sync_every
        self.donate = donate
        # scan unroll factor: >1 trades compile time (and code size) for
        # fewer while-loop carry round-trips; worthwhile for tiny models
        self.unroll = unroll
        if device_datagen is None:
            device_datagen = isinstance(data, SyntheticLM)
        self._on_device_data = device_datagen
        self._batch_at = (
            device_batch_fn(data, trainer.M, batch_seqs) if device_datagen else None
        )
        self._prefetch = (
            None if device_datagen else RoundPrefetcher(data, trainer.M, batch_seqs)
        )
        self._frag = (
            streaming.FragmentSync(trainer)
            if (dcfg.streaming_fragments > 0 and not dcfg.data_parallel)
            else None
        )
        self._rounds: Dict[Tuple[int, bool], Any] = {}

    # ---- compiled round -------------------------------------------------
    def _round_fn(self, length: int, do_sync: bool):
        key = (length, do_sync)
        fn = self._rounds.get(key)
        if fn is None:
            fn = jax.jit(
                self._make_round(length, do_sync),
                donate_argnums=(0,) if self.donate else (),
            )
            self._rounds[key] = fn
        return fn

    def _make_round(self, length: int, do_sync: bool):
        tr = self.trainer
        H = tr.dcfg.sync_every
        P = tr.dcfg.streaming_fragments

        def round_fn(state, xs, weights):
            def body(st, x):
                batch = self._batch_at(st["step"]) if self._on_device_data else x
                st, metrics = tr.inner_step(st, batch)
                if self._frag is not None:
                    # mid-round fragment syncs at their scheduled steps
                    # (st["step"] is post-increment, i.e. 1-based like the
                    # per-step loop's `step + 1`)
                    for p in range(P):
                        st = jax.lax.cond(
                            streaming.is_due(st["step"], p, P, H),
                            lambda s, p=p: self._frag.apply(s, p),
                            lambda s: s,
                            st,
                        )
                return st, metrics

            state, metrics = jax.lax.scan(
                body, state, xs, length=length,
                unroll=min(self.unroll, length),
            )
            if do_sync and self._frag is None and not tr.dcfg.data_parallel:
                state = tr.outer_sync(state, weights)
            return state, metrics

        return round_fn

    # ---- driving --------------------------------------------------------
    def run_round(self, state, start: int, length: Optional[int] = None, weights=None,
                  next_length: Optional[int] = None):
        """Run ``length`` inner steps from global step ``start`` (plus the
        outer sync if the round ends on an H boundary) as one executable.

        Returns ``(state, metrics)`` where metrics is a dict of host numpy
        arrays of shape ``(length,)`` — the single host sync of the round.
        CONSUMES ``state`` (buffer donation).  ``next_length`` is a prefetch
        hint for file-backed data (0 = last round, don't prefetch); direct
        drivers that omit it should call ``engine.close()`` after the final
        round to release the speculative readahead.
        """
        length = self.chunk if length is None else length
        end = start + length
        dcfg = self.trainer.dcfg
        if not dcfg.data_parallel and self._frag is None:
            # a window crossing an interior H boundary would silently skip
            # that boundary's outer sync (the executable syncs only at its
            # end); run() splits windows so this can't happen
            boundary = (start // self.chunk + 1) * self.chunk
            if end > boundary:
                raise ValueError(
                    f"round [{start}, {end}) crosses the outer-sync boundary "
                    f"at step {boundary}; split windows at multiples of "
                    f"sync_every={self.chunk} (engine.run does this)"
                )
        do_sync = (end % self.chunk == 0) and not dcfg.data_parallel
        xs = None
        if not self._on_device_data:
            xs = self._prefetch.get(start, length, next_length)
        state, metrics = self._round_fn(length, do_sync)(state, xs, weights)
        return state, jax.device_get(metrics)

    def round_bounds(self, step: int, steps: int) -> Tuple[int, int]:
        """Round schedule when driving ``step -> steps``: returns ``(end,
        next_length)`` — the current round's end (split at chunk boundaries)
        and the following round's length (the prefetch hint; 0 at the end).
        External drivers (the train loop) use this so the alignment
        invariants live in one place."""
        end = min(steps, (step // self.chunk + 1) * self.chunk)
        nxt = min(steps, (end // self.chunk + 1) * self.chunk) - end
        return end, nxt

    def run(self, state, steps: int, start: int = 0):
        """Drive ``start..steps`` in H-aligned rounds (tail round compiled
        once at its shorter length).  Returns ``(state, metrics)`` with
        metrics concatenated to ``(steps - start,)`` host arrays."""
        collected = []
        step = start
        while step < steps:
            end, nxt = self.round_bounds(step, steps)
            state, m = self.run_round(state, step, end - step, next_length=nxt)
            collected.append(m)
            step = end
        if not collected:
            return state, {}
        metrics = {
            k: np.concatenate([np.atleast_1d(m[k]) for m in collected])
            for k in collected[0]
        }
        return state, metrics

    def close(self) -> None:
        """Release the data prefetcher's pending readahead (no-op for
        on-device generation)."""
        if self._prefetch is not None:
            self._prefetch.close()
