"""Compiled H-step superstep engine: one executable per outer round.

The paper's wall-clock argument (and the ROADMAP's "fast as the hardware
allows" north star) makes the inner loop the hot path: DiLoCo syncs every H
steps precisely so that the other H-1 steps run at hardware speed.  A
per-step Python loop gives that speed back — one dispatch per inner step, a
host-built batch per step, a full state copy per call (no donation), and a
blocking ``float(metrics["loss"])`` host sync per step.

``SuperstepEngine`` removes all of it.  One jitted, donated executable runs
an entire outer round:

* ``lax.scan`` over the H inner steps;
* on-device batch generation — for ``SyntheticLM`` the step counter is
  folded into the PRNG key *inside* the scan body (bitwise-identical
  batches to the host path, zero host->device traffic); file-backed
  sources get a double-buffered ``device_put`` prefetcher instead;
* the outer sync in the same executable — whatever ``SyncStrategy`` the
  trainer carries (``repro.core.sync``): full-precision, quantized
  (int8/int4 error feedback rides in the donated state), or fragment-wise
  streaming-style strategies (``lax.cond`` on the strategy's fragment
  schedule inside the scan body, so mid-round fragment syncs land on
  exactly the step the per-step loop would run them);
* stacked ``(H, ...)`` metrics returned to the host — ONE host sync per
  outer round instead of one per step.

Cross-cell executable reuse: the round executable is a pure function of the
trainer's *static signature* (``repro.core.diloco.static_signature``) —
scalar hyperparameters come from the state's ``hparams`` leaf and the
synthetic data source's PRNG root / transition table are passed as OPERANDS
(not closure constants), so round executables are cached process-wide
(``repro.core.jitcache``): a sweep of cells that differ only in lr / seed /
outer-optimizer scalars compiles each round shape exactly once.  The same
round body, vmapped over a leading cell axis, powers the cell-batched
sweep engine (``repro.core.cellbatch``).

Donation caveat: the state passed to ``run_round``/``run`` is CONSUMED
(XLA aliases its buffers for the update).  Rebind ``state = engine.run_*``
and never touch the old reference.
"""
from __future__ import annotations

import concurrent.futures
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jitcache
from repro.core.diloco import static_signature
from repro.data import SyntheticLM
from repro.data.pipeline import synthetic_tokens


def device_batch(root: jax.Array, logits: jax.Array, step: jax.Array,
                 num_replicas: int, batch_seqs: int, seq_len: int) -> dict:
    """Traceable global batch at ``step`` from a SyntheticLM's PRNG root and
    transition table, bitwise-equal to ``data.global_batch(step, M, b)``.

    ``root``/``logits`` are operands: the caller passes ``data._root`` /
    ``data._logits`` at dispatch time, so one compiled executable serves
    every data seed (and the cell-batched engine vmaps them over cells).
    """
    M = num_replicas
    key = jax.random.fold_in(root, step)

    def one(m):
        k = jax.random.fold_in(key, m + M * 7919)
        return synthetic_tokens(logits, k, batch_seqs, seq_len)

    toks = jax.vmap(one)(jnp.arange(M))  # (M, b, L+1)
    return {
        "tokens": toks[..., :-1].astype(jnp.int32),
        "labels": toks[..., 1:].astype(jnp.int32),
    }


def device_batch_fn(data: SyntheticLM, num_replicas: int, batch_seqs: int) -> Callable:
    """Convenience closure form of ``device_batch`` bound to one source:
    traceable ``step -> global batch``."""

    def batch_at(step: jax.Array) -> dict:
        return device_batch(data._root, data._logits, step,
                           num_replicas, batch_seqs, data.seq_len)

    return batch_at


def round_body(trainer, length: int, do_sync: bool, *, batch_seqs: int,
               seq_len: int, on_device_data: bool, unroll: int = 1) -> Callable:
    """The traceable superstep round shared by ``SuperstepEngine`` (jitted
    directly) and ``CellBatchEngine`` (vmapped over a leading cell axis).

    Returns ``round_fn(state, xs, droot, dlogits, weights)``:

    * ``xs`` — stacked ``(length, M, b, L)`` host batches (file-backed
      sources); ``None`` with on-device generation;
    * ``droot``/``dlogits`` — the SyntheticLM PRNG root + transition table
      operands for on-device generation; ``None`` otherwise;
    * ``weights`` — optional (M,) outer participation weights.

    The outer sync is whatever the trainer's ``SyncStrategy`` defines:
    fragment-wise strategies (``num_fragments > 0``) embed their mid-round
    syncs behind ``lax.cond`` inside the scan body; round-pinned strategies
    apply once at the end when ``do_sync``.  Depends on ``trainer`` only
    through its static signature (hyperparams ride in ``state["hparams"]``),
    which is what makes the compiled form shareable across same-shape
    trainers.
    """
    strat = trainer.sync
    H = trainer.dcfg.sync_every
    P = strat.num_fragments
    M = trainer.M
    frag_apply = strat.fragment_applier(trainer) if P > 0 else None

    def round_fn(state, xs, droot, dlogits, weights):
        def body(st, x):
            if on_device_data:
                batch = device_batch(droot, dlogits, st["step"], M,
                                     batch_seqs, seq_len)
            else:
                batch = x
            st, metrics = trainer.inner_step(st, batch)
            if frag_apply is not None:
                # mid-round fragment syncs at their scheduled steps
                # (st["step"] is post-increment, i.e. 1-based like the
                # per-step loop's `step + 1`)
                for p in range(P):
                    st = jax.lax.cond(
                        strat.fragment_due(st["step"], p, H),
                        lambda s, p=p: frag_apply(s, p),
                        lambda s: s,
                        st,
                    )
            return st, metrics

        state, metrics = jax.lax.scan(
            body, state, xs, length=length,
            unroll=min(unroll, length),
        )
        if do_sync:
            state = strat.apply(trainer, state, weights)
        return state, metrics

    return round_fn


class RoundPrefetcher:
    """Double-buffered host->device batch pipeline for file-backed sources.

    While round r executes on device, a worker thread assembles round r+1's
    stacked ``(H, M, b, L)`` batch and ``device_put``s it, so in steady
    state the engine never blocks on host-side batch assembly or transfer.
    """

    def __init__(self, data: Any, num_replicas: int, batch_seqs: int):
        self._data = data
        self._m = num_replicas
        self._bs = batch_seqs
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Dict[Tuple[int, int], concurrent.futures.Future] = {}
        self._closed = threading.Event()
        # a worker-thread failure parks here and re-raises at the next
        # get() — a raising data source must never be silently discarded
        # with a mispredicted future
        self._error: Optional[BaseException] = None

    def _build(self, start: int, length: int):
        rounds = []
        for i in range(length):
            if self._closed.is_set():
                return None
            rounds.append(self._data.global_batch(start + i, self._m, self._bs))
        stacked = jax.tree.map(
            lambda *xs: np.stack([np.asarray(x) for x in xs]), *rounds
        )
        # checked immediately before the transfer: a close() racing an
        # in-flight speculative build must not land device buffers it can
        # no longer release
        if self._closed.is_set():
            return None
        return jax.device_put(stacked)

    def _submit(self, start: int, length: int):
        def task():
            try:
                return self._build(start, length)
            except BaseException as e:  # surfaced at the next get()
                self._error = e
                return None

        return self._pool.submit(task)

    def schedule(self, start: int, length: int) -> None:
        key = (start, length)
        if key not in self._pending:
            self._pending[key] = self._submit(start, length)

    def get(self, start: int, length: int, next_length: Optional[int] = None):
        """Return the (start, length) round; prefetch the following round of
        ``next_length`` steps (default: same length; 0 = end of training,
        prefetch nothing).  Mis-predicted pending rounds are discarded so
        stale batches don't pin device memory.  A data-source exception on
        the worker thread re-raises here, at the next fetch — never
        silently swallowed with a discarded future."""
        if self._closed.is_set():
            raise RuntimeError("RoundPrefetcher is closed")
        err, self._error = self._error, None
        if err is not None:
            raise err
        fut = self._pending.pop((start, length), None)
        for stale in list(self._pending):
            self._pending.pop(stale).cancel()
        xs = fut.result() if fut is not None else None
        if xs is None:  # unscheduled, lost a race with close(), or failed
            try:
                # a worker failure for THIS round lands here too: rebuild
                # synchronously so the original error (re-)raises in the
                # caller, and drop the parked copy — it has been delivered
                xs = self._build(start, length)
            finally:
                self._error = None
        next_length = length if next_length is None else next_length
        if next_length > 0:
            self.schedule(start + length, next_length)
        return xs

    def close(self) -> None:
        """Stop the worker and drop any pending readahead — including a
        ``_build`` already running: queued futures are cancelled
        (``cancel_futures=True``), and an in-flight build observes
        ``_closed`` and bails before its ``device_put``, so no speculative
        batch can land on device after close and stay pinned there.  Call
        after the last round when driving ``run_round`` directly without
        the ``next_length=0`` end hint."""
        self._closed.set()
        for key in list(self._pending):
            self._pending.pop(key).cancel()
        self._pool.shutdown(wait=False, cancel_futures=True)


class SuperstepEngine:
    """Runs training one compiled, donated outer round per dispatch.

    ``chunk`` (default ``dcfg.sync_every``) is the scan length; rounds that
    end on an H boundary include the outer sync in the same executable.
    Round executables are shared process-wide across engines whose trainers
    agree on ``static_signature`` (disable with ``share=False`` or the
    ``jitcache.sharing(False)`` context).
    """

    def __init__(
        self,
        trainer,
        data,
        batch_seqs: int,
        *,
        chunk: int = 0,
        donate: bool = True,
        device_datagen: Optional[bool] = None,
        unroll: int = 1,
        share: bool = True,
    ):
        dcfg = trainer.dcfg
        if chunk and trainer.sync.uses_outer_opt and chunk != dcfg.sync_every:
            raise ValueError(
                f"chunk ({chunk}) must equal sync_every ({dcfg.sync_every}) "
                "for DiLoCo; a free chunk length is only meaningful for DP"
            )
        self.trainer = trainer
        self.data = data
        self.batch_seqs = batch_seqs
        self.chunk = chunk or dcfg.sync_every
        self.donate = donate
        # scan unroll factor: >1 trades compile time (and code size) for
        # fewer while-loop carry round-trips; worthwhile for tiny models
        self.unroll = unroll
        self.share = share
        if device_datagen is None:
            device_datagen = isinstance(data, SyntheticLM)
        self._on_device_data = device_datagen
        self._prefetch = (
            None if device_datagen else RoundPrefetcher(data, trainer.M, batch_seqs)
        )
        self._local_rounds: Dict[Tuple, Any] = {}

    # ---- compiled round -------------------------------------------------
    def _round_fn(self, length: int, do_sync: bool):
        key = (
            "superstep", static_signature(self.trainer), length, do_sync,
            self.donate, min(self.unroll, length), self._on_device_data,
            self.batch_seqs, self.data.seq_len,
        )

        def build():
            fn = round_body(
                self.trainer, length, do_sync,
                batch_seqs=self.batch_seqs, seq_len=self.data.seq_len,
                on_device_data=self._on_device_data, unroll=self.unroll,
            )
            return jax.jit(fn, donate_argnums=(0,) if self.donate else ())

        if not self.share:
            fn = self._local_rounds.get(key)
            if fn is None:
                fn = self._local_rounds[key] = build()
            return fn
        return jitcache.get_or_build(key, build, self._local_rounds)

    # ---- driving --------------------------------------------------------
    def run_round(self, state, start: int, length: Optional[int] = None, weights=None,
                  next_length: Optional[int] = None):
        """Run ``length`` inner steps from global step ``start`` (plus the
        outer sync if the round ends on an H boundary) as one executable.

        Returns ``(state, metrics)`` where metrics is a dict of host numpy
        arrays of shape ``(length,)`` — the single host sync of the round.
        CONSUMES ``state`` (buffer donation).  ``next_length`` is a prefetch
        hint for file-backed data (0 = last round, don't prefetch); direct
        drivers that omit it should call ``engine.close()`` after the final
        round to release the speculative readahead.
        """
        length = self.chunk if length is None else length
        end = start + length
        if self.trainer.sync.pins_round_boundary:
            # a window crossing an interior H boundary would silently skip
            # that boundary's outer sync (the executable syncs only at its
            # end); run() splits windows so this can't happen
            boundary = (start // self.chunk + 1) * self.chunk
            if end > boundary:
                raise ValueError(
                    f"round [{start}, {end}) crosses the outer-sync boundary "
                    f"at step {boundary}; split windows at multiples of "
                    f"sync_every={self.chunk} (engine.run does this)"
                )
        do_sync = (end % self.chunk == 0) and self.trainer.sync.pins_round_boundary
        xs = droot = dlogits = None
        if self._on_device_data:
            droot, dlogits = self.data._root, self.data._logits
        else:
            xs = self._prefetch.get(start, length, next_length)
        state, metrics = self._round_fn(length, do_sync)(
            state, xs, droot, dlogits, weights)
        return state, jax.device_get(metrics)

    def round_bounds(self, step: int, steps: int) -> Tuple[int, int]:
        """Round schedule when driving ``step -> steps``: returns ``(end,
        next_length)`` — the current round's end (split at chunk boundaries)
        and the following round's length (the prefetch hint; 0 at the end).
        External drivers (the train loop) use this so the alignment
        invariants live in one place."""
        end = min(steps, (step // self.chunk + 1) * self.chunk)
        nxt = min(steps, (end // self.chunk + 1) * self.chunk) - end
        return end, nxt

    def run(self, state, steps: int, start: int = 0):
        """Drive ``start..steps`` in H-aligned rounds (tail round compiled
        once at its shorter length).  Returns ``(state, metrics)`` with
        metrics concatenated to ``(steps - start,)`` host arrays."""
        collected = []
        step = start
        while step < steps:
            end, nxt = self.round_bounds(step, steps)
            state, m = self.run_round(state, step, end - step, next_length=nxt)
            collected.append(m)
            step = end
        if not collected:
            return state, {}
        metrics = {
            k: np.concatenate([np.atleast_1d(m[k]) for m in collected])
            for k in collected[0]
        }
        return state, metrics

    def close(self) -> None:
        """Release the data prefetcher's pending readahead (no-op for
        on-device generation)."""
        if self._prefetch is not None:
            self._prefetch.close()
