"""DiLoCo trainer — the paper's Algorithm 1 as a first-class JAX module.

The M model replicas live on a leading "replica" axis of every inner-state
leaf, sharded over the mesh's replica/pod axis (DrJAX-style: ``jax.vmap``
over the axis + sharding constraints give GSPMD explicit replica
parallelism).  Inner steps are AdamW on each replica's own data shard; every
H steps the outer gradients ``Δ_m = θ_global - θ_m`` are averaged — the ONLY
cross-pod collective — and SGD+Nesterov updates the global model, which is
re-broadcast.

Data-Parallel is the ``data_parallel=True`` special case (no outer step);
DiLoCo with M=1 is the paper's Lookahead-style variant (outer step kept).

WHAT the outer sync does — full-precision averaging, int8/int4 quantization
with error feedback, fragment-wise streaming, or any registered variant —
is owned by the trainer's pluggable ``SyncStrategy`` (``repro.core.sync``,
selected via ``DiLoCoConfig.sync`` or the legacy flag triple): the strategy
contributes the extra state leaves, the in-graph ``outer_sync`` transform,
the engines' scheduling capabilities, and its part of ``static_signature``.

Two execution paths share the same functions:
  * ``inner_step`` / ``outer_sync``: separate executables for the real
    training loop (H handled in Python — no per-step cond overhead);
  * ``train_step``: fused single executable with ``lax.cond`` on
    ``step % H == 0`` — used by the multi-pod dry-run so the whole
    communication schedule (including the cross-pod all-reduce) is visible
    in one compiled HLO.

Scalar hyperparameters (inner peak lr / warmup / weight decay, outer lr /
momentum) are TRACED: ``init_state`` puts them in the state's ``hparams``
leaf as 0-d arrays and ``_replica_step``/``outer_sync`` read them from
there instead of baking ``self.ocfg``/``self.dcfg`` Python constants into
the executable.  Two trainers that differ only in those scalars therefore
produce identical jaxprs — the foundation for cross-cell executable
sharing (``repro.core.jitcache``) and for the cell-batched sweep engine
(``repro.core.cellbatch``), which stacks per-cell hyperparameters along a
leading cell axis and vmaps over them.  Every execution path that reads
``hparams`` (per-step, superstep, stacked) is bitwise-consistent with
every other; note the results can differ from the PRE-hparams executables
by ~1 ulp, because XLA could constant-fold a baked Python scalar (e.g.
rewrite the warmup division into a reciprocal multiply) where a traced
operand stays a true divide.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import DiLoCoConfig, OptimizerConfig, TrainConfig
from repro.core import jitcache, outer_opt
from repro.core import sync as sync_lib
from repro.models.build import Model
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.adamw import abstract_adamw_state
from repro.optim.schedules import warmup_cosine


def static_signature(trainer: "DiLoCo") -> tuple:
    """Everything that shapes a trainer's jaxprs, and nothing more.

    The traced hyperparameters (peak_lr, warmup_steps, weight_decay,
    outer_lr, outer_momentum) are deliberately EXCLUDED: they live in the
    state's ``hparams`` leaf, so trainers differing only in them produce
    identical jaxprs and may share compiled executables.
    """
    o, d, t = trainer.ocfg, trainer.dcfg, trainer.tcfg
    return (
        trainer.model.cfg,
        (d.num_replicas, d.sync_every, d.nesterov,
         trainer.sync.static_signature()),
        (o.b1, o.b2, o.eps, o.clip_norm, o.final_lr_ratio),
        (t.global_batch_tokens, t.seq_len, t.steps, t.microbatches),
        jitcache.context_key(),
    )


@dataclasses.dataclass
class DiLoCo:
    model: Model
    dcfg: DiLoCoConfig
    ocfg: OptimizerConfig
    tcfg: TrainConfig
    # per-instance fallback cache, used when process-wide sharing is off
    _jit_cache: dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False
    )
    # resolved-once sync strategy (pure function of dcfg)
    _sync: Optional[sync_lib.SyncStrategy] = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )

    @property
    def sync(self) -> sync_lib.SyncStrategy:
        """The trainer's outer-sync strategy (``repro.core.sync``), resolved
        from ``dcfg.sync`` or — deprecation shim — the legacy flag triple."""
        if self._sync is None:
            self._sync = sync_lib.resolve(self.dcfg)
        return self._sync

    # ---- compiled entry points -------------------------------------------
    # State-carrying hot-path executables donate their state argument so the
    # update is in-place (XLA aliases the buffers).  Callers must treat the
    # passed-in state as CONSUMED: rebind `state = fn(state, ...)` and never
    # touch the old reference again.
    #
    # Executables are cached process-wide by static_signature(): two trainer
    # instances that agree structurally (and differ at most in the traced
    # hyperparameters) share one compiled executable per entry point.
    def jit_inner_step(self, donate: bool = True):
        return self._jitted("inner_step", self.inner_step, donate)

    def jit_outer_sync(self, donate: bool = True):
        return self._jitted("outer_sync", self.outer_sync, donate)

    def jit_eval_step(self):
        return self._jitted("eval_step", self.eval_step, False)

    def _jitted(self, name: str, fn, donate: bool):
        key = ("diloco", static_signature(self), name, donate)
        return jitcache.get_or_build(
            key, lambda: jax.jit(fn, donate_argnums=(0,) if donate else ()),
            self._jit_cache,
        )

    # ------------------------------------------------------------------
    @property
    def weight_decay(self) -> float:
        # paper §3 (Wang & Aitchison): lambda = 1/T
        if self.ocfg.weight_decay >= 0:
            return self.ocfg.weight_decay
        return 1.0 / max(self.tcfg.steps, 1)

    @property
    def M(self) -> int:
        return self.dcfg.num_replicas

    @property
    def sync_mode(self) -> str:
        """Outer-sync flavor, as recorded in checkpoint manifests — the
        strategy's manifest tag (``dp`` / ``none`` (full-precision) /
        ``int8`` / ``streaming`` / ``int4`` / any registered strategy's)."""
        return self.sync.tag

    # ---- traced hyperparameters ------------------------------------------
    def hparams(self) -> dict:
        """The scalar hyperparameters the executables read from the state's
        ``hparams`` leaf (0-d device arrays, traced — NOT baked constants).
        ``weight_decay`` is pre-resolved (the ``-1 -> 1/T`` rule is Python
        logic, not something to re-derive in-graph)."""
        hp = {
            "peak_lr": jnp.float32(self.ocfg.peak_lr),
            "warmup": jnp.int32(self.ocfg.warmup_steps),
            "weight_decay": jnp.float32(self.weight_decay),
        }
        if self.sync.uses_outer_opt:
            hp["outer_lr"] = jnp.float32(self.dcfg.outer_lr)
            hp["outer_momentum"] = jnp.float32(self.dcfg.outer_momentum)
        return hp

    def abstract_hparams(self) -> dict:
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.hparams()
        )

    # ---- state ------------------------------------------------------------
    def init_state(self, key: jax.Array, dtype=jnp.float32) -> dict:
        gparams = self.model.init(key, dtype)
        inner = jax.tree.map(lambda x: jnp.repeat(x[None], self.M, 0), gparams)
        opt1 = adamw_init(gparams)
        inner_opt = jax.tree.map(lambda x: jnp.repeat(x[None], self.M, 0), opt1)
        state = {
            "step": jnp.zeros((), jnp.int32),
            "inner_params": inner,
            "inner_opt": inner_opt,
            "hparams": self.hparams(),
        }
        if self.sync.uses_outer_opt:
            state["global_params"] = gparams
            state["outer_m"] = outer_opt.outer_init(gparams)
            state.update(self.sync.extra_state(self, gparams))
        return state

    def abstract_state(self, dtype=jnp.bfloat16) -> dict:
        gparams = self.model.abstract_params(dtype)

        def lead(t):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((self.M, *s.shape), s.dtype), t
            )

        state = {
            "step": jax.ShapeDtypeStruct((), jnp.int32),
            "inner_params": lead(gparams),
            "inner_opt": lead(abstract_adamw_state(gparams)),
            "hparams": self.abstract_hparams(),
        }
        if self.sync.uses_outer_opt:
            state["global_params"] = gparams
            state["outer_m"] = outer_opt.abstract_outer_state(gparams)
            state.update(self.sync.abstract_extra_state(self, gparams))
        return state

    def state_partition_specs(self) -> dict:
        """PartitionSpecs for the state under the current sharding rules.

        ZeRO-1 support: if the rules define "opt_embed", the AdamW moments
        shard their weight-embed dim over that axis while the *params* keep
        the plain "embed" rule (e.g. params replicated over data for
        gather-free compute, fp32 moments sharded over data — GSPMD inserts
        the grad reduce-scatter + param all-gather around the update).
        """
        pspec = self.model.param_partition_specs
        rules = sharding.current_rules()

        def opt_spec(extra):
            if "opt_embed" in rules:
                overlay = dict(rules)
                overlay["embed"] = overlay["opt_embed"]
                with sharding.use_rules(overlay):
                    return self.model.param_partition_specs(extra_leading=extra)
            return pspec(extra_leading=extra)

        rep = ("replica",)
        specs = {
            "step": sharding.spec(),
            "inner_params": pspec(extra_leading=rep),
            "inner_opt": {
                "m": opt_spec(rep),
                "v": opt_spec(rep),
                "count": sharding.spec("replica"),
            },
            "hparams": {k: sharding.spec() for k in self.hparams()},
        }
        if self.sync.uses_outer_opt:
            specs["global_params"] = pspec()
            specs["outer_m"] = pspec()
            specs.update(self.sync.extra_state_partition_specs(self, pspec))
        return specs

    def batch_partition_specs(self, batch) -> dict:
        """Batch leaves carry a leading replica axis then (batch, seq, ...)."""

        def one(leaf):
            names = ["replica", "batch", "seq"] + [None] * max(0, leaf.ndim - 3)
            return sharding.spec(*names[: leaf.ndim])

        return jax.tree.map(one, batch)

    # ---- inner step ----------------------------------------------------------
    def _replica_step(self, params, opt, batch, step, hp):
        k = self.tcfg.microbatches
        if k > 1:
            # gradient accumulation: scan over k microbatches (sequential in
            # time on the real machine; grads averaged before the update)
            split = jax.tree.map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch
            )

            def body(carry, mb):
                g_acc, l_acc, m_acc = carry
                (l, m), g = jax.value_and_grad(
                    lambda p: self.model.loss_fn(p, mb), has_aux=True
                )(params)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / k, g_acc, g
                )
                m_acc = jax.tree.map(lambda a, b: a + b / k, m_acc, m)
                return (g_acc, l_acc + l / k, m_acc), None

            zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            _, m0 = jax.eval_shape(
                lambda p: self.model.loss_fn(p, jax.tree.map(lambda x: x[0], split)), params
            )
            zeros_m = jax.tree.map(lambda s: jnp.zeros((), jnp.float32), m0)
            (grads, loss_val, metrics), _ = jax.lax.scan(
                body, (zeros_g, jnp.zeros(()), zeros_m), split
            )
        else:
            def loss(p):
                return self.model.loss_fn(p, batch)

            (loss_val, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, self.ocfg.clip_norm)
        lr = warmup_cosine(
            step + 1,  # 1-based: step 0 would otherwise burn a batch at lr=0
            peak_lr=hp["peak_lr"],
            warmup=hp["warmup"],
            total=self.tcfg.steps,
            final_ratio=self.ocfg.final_lr_ratio,
        )
        params, opt = adamw_update(
            params, grads, opt,
            lr=lr, b1=self.ocfg.b1, b2=self.ocfg.b2, eps=self.ocfg.eps,
            weight_decay=hp["weight_decay"],
        )
        metrics = dict(metrics)
        metrics["loss"] = loss_val
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt, metrics

    def inner_step(self, state: dict, batch: dict) -> Tuple[dict, dict]:
        """One inner AdamW step on every replica (vmapped over the M axis)."""
        step = state["step"]
        params, opt, metrics = jax.vmap(
            self._replica_step, in_axes=(0, 0, 0, None, None)
        )(state["inner_params"], state["inner_opt"], batch, step,
          state["hparams"])
        params = self._constrain(params)
        state = {**state, "inner_params": params, "inner_opt": opt, "step": step + 1}
        metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), metrics)
        return state, metrics

    def _constrain(self, inner_params):
        rules = sharding.current_rules()
        if not rules:
            return inner_params
        specs = self.model.param_partition_specs(extra_leading=("replica",))
        return sharding.tree_constrain(inner_params, specs)

    # ---- outer step -------------------------------------------------------------
    def outer_sync(self, state: dict, weights: Optional[jax.Array] = None) -> dict:
        """Outer gradient all-reduce + outer step + broadcast, as defined by
        the trainer's sync strategy (``repro.core.sync``) — full-precision,
        quantized (int8/int4 with error feedback), or any registered
        variant.

        ``weights``: optional (M,) participation weights (straggler dropout /
        partial participation).  Default: uniform 1/M.
        """
        return self.sync.apply(self, state, weights)

    # ---- fused step (dry-run / single-executable loops) ----------------------------
    def train_step(self, state: dict, batch: dict) -> Tuple[dict, dict]:
        state, metrics = self.inner_step(state, batch)
        if not self.sync.uses_outer_opt:
            return state, metrics
        sync_now = (state["step"] % self.dcfg.sync_every) == 0
        state = jax.lax.cond(sync_now, self.outer_sync, lambda s: s, state)
        return state, metrics

    # ---- evaluation -------------------------------------------------------------------
    def eval_params(self, state: dict):
        """Paper §2.2: evaluate the most recent *global* model (DP: the model)."""
        if not self.sync.uses_outer_opt:
            return jax.tree.map(lambda p: p[0], state["inner_params"])
        return state["global_params"]

    def eval_step(self, state: dict, batch: dict) -> jax.Array:
        """batch WITHOUT replica axis; returns scalar eval nll."""
        params = self.eval_params(state)
        _, metrics = self.model.loss_fn(params, batch)
        return metrics["nll"]


def make_trainer(model: Model, dcfg: DiLoCoConfig, ocfg: OptimizerConfig, tcfg: TrainConfig) -> DiLoCo:
    if dcfg.data_parallel:
        assert dcfg.num_replicas == 1, "Data-Parallel is the M=1, no-outer-opt case"
    trainer = DiLoCo(model, dcfg, ocfg, tcfg)
    trainer.sync  # resolve + validate the sync strategy (fail fast on bad specs)
    return trainer
