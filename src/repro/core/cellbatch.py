"""Cell-batched sweep execution: K grid cells as ONE donated executable.

The paper's headline artifact is a grid of runs (Tables 7-13 sweep
N x M x H x B x sync-mode over many small models), and most of that grid
varies only *scalar* hyperparameters — inner lr, outer lr / momentum, data
seed — between cells of identical shape.  Running those cells sequentially
pays per-cell dispatch overhead K times and leaves the hardware's batch
dimension idle; with hyperparameters traced through the state's ``hparams``
leaf (``repro.core.diloco``) and synthetic-data operands threaded through
the round (``repro.core.superstep.round_body``), the entire round body is a
pure function of per-cell arrays — so K shape-compatible cells can be
stacked along a leading ``cell`` axis and vmapped into one compiled,
donated superstep per outer round.

``CellBatchEngine`` is that path.  Requirements for stacking (enforced):

* identical static signature (same arch, B, seq_len, M, H, steps budget,
  sync mode, nesterov flag, fragment count) — cells may differ ONLY in the
  traced hyperparameters and the data/init seeds;
* on-device synthetic data (``SyntheticLM``) — per-cell PRNG roots and
  transition tables are stacked operands; file-backed sources stay on the
  sequential engine;
* no ambient sharding rules (the sweep runs cells unsharded; the leading
  cell axis would otherwise collide with the replica-axis constraints).

Per-cell results are bitwise-identical to the sequential ``SuperstepEngine``
on this backend (vmap adds a batch dimension to every op; it does not
change per-cell reduction order) —
``tests/test_engine.py::test_cellbatch_matches_superstep_per_cell`` pins
this for every registered sync strategy (dp/full/int8/int4/streaming), and
``tests/test_sweep.py`` pins ledger equality end to end.

Donation caveat: as with the superstep engine, the stacked state passed to
``run_round``/``run`` is CONSUMED.  Rebind ``states = engine.run(...)``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.core import jitcache
from repro.core.diloco import static_signature
from repro.core.superstep import round_body
from repro.data import SyntheticLM


def stack_trees(trees: Sequence[Any]):
    """Stack a list of identically-shaped pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_tree(tree: Any, k: int):
    """Slice cell ``k`` out of a stacked pytree (device-side gather)."""
    return jax.tree.map(lambda x: x[k], tree)


class CellBatchEngine:
    """Runs K stacked cells, one compiled donated round per dispatch.

    ``trainers``: one ``DiLoCo`` per cell — all must share
    ``static_signature`` (they may differ only in the traced
    hyperparameters, which ride in each cell's ``hparams`` state leaf).
    ``datas``: one ``SyntheticLM`` per cell (seeds may differ).
    """

    def __init__(
        self,
        trainers: Sequence[Any],
        datas: Sequence[SyntheticLM],
        batch_seqs: int,
        *,
        unroll: int = 1,
        donate: bool = True,
        share: bool = True,
    ):
        if len(trainers) != len(datas) or not trainers:
            raise ValueError("need one data source per trainer (and K >= 1)")
        if sharding.current_rules():
            raise ValueError(
                "CellBatchEngine stacks cells along a leading axis and does "
                "not compose with ambient sharding rules; run cells "
                "unsharded (the sweep driver does) or use SuperstepEngine"
            )
        sigs = {static_signature(t) for t in trainers}
        if len(sigs) != 1:
            raise ValueError(
                "all stacked cells must share one static signature (same "
                f"arch/M/H/B/steps/sync-mode); got {len(sigs)} distinct"
            )
        for d in datas:
            if not isinstance(d, SyntheticLM):
                raise ValueError(
                    "cell batching requires on-device SyntheticLM data; "
                    "file-backed cells run on the sequential engine"
                )
        shapes = {(d.seq_len, d._logits.shape) for d in datas}
        if len(shapes) != 1:
            raise ValueError(f"data sources disagree on shape: {shapes}")

        self.trainers = list(trainers)
        self.trainer = trainers[0]
        self.K = len(trainers)
        self.datas = list(datas)
        self.batch_seqs = batch_seqs
        self.chunk = self.trainer.dcfg.sync_every
        self.donate = donate
        self.unroll = unroll
        self.share = share
        self.seq_len = datas[0].seq_len
        # stacked per-cell datagen operands: (K, 2) PRNG roots, (K, D, V, V)
        # transition tables
        self._droot = jnp.stack([d._root for d in datas])
        self._dlogits = jnp.stack([d._logits for d in datas])
        self._local_rounds: Dict[Tuple, Any] = {}

    # ---- state ----------------------------------------------------------
    def init_states(self, seeds: Sequence[int]) -> dict:
        """Per-cell ``init_state(PRNGKey(seed))`` stacked along the cell
        axis; each cell's ``hparams`` leaf carries its own scalars."""
        if len(seeds) != self.K:
            raise ValueError(f"need {self.K} seeds, got {len(seeds)}")
        return stack_trees([
            t.init_state(jax.random.PRNGKey(s))
            for t, s in zip(self.trainers, seeds)
        ])

    # ---- compiled round -------------------------------------------------
    def _round_fn(self, length: int, do_sync: bool, has_weights: bool = False):
        key = (
            "cellbatch", static_signature(self.trainer), self.K, length,
            do_sync, self.donate, min(self.unroll, length), self.batch_seqs,
            self.seq_len, has_weights,
        )

        def build():
            fn = round_body(
                self.trainer, length, do_sync,
                batch_seqs=self.batch_seqs, seq_len=self.seq_len,
                on_device_data=True, unroll=self.unroll,
            )
            # cell axis: state / datagen operands are per-cell; xs is unused
            # on this path (None pytree); participation weights, when
            # present, are per-cell (K, M) — a traced operand, so every
            # mask sequence reuses this one executable
            vfn = jax.vmap(fn, in_axes=(0, None, 0, 0, 0 if has_weights else None))
            return jax.jit(vfn, donate_argnums=(0,) if self.donate else ())

        if not self.share:
            fn = self._local_rounds.get(key)
            if fn is None:
                fn = self._local_rounds[key] = build()
            return fn
        return jitcache.get_or_build(key, build, self._local_rounds)

    # ---- driving --------------------------------------------------------
    def run_round(self, states, start: int, length: Optional[int] = None,
                  weights=None):
        """One stacked round: ``length`` inner steps for all K cells (plus
        the outer sync on H boundaries) in one executable.  Returns
        ``(states, metrics)`` with metrics as ``(K, length)`` host arrays.
        ``weights``: optional (K, M) per-cell outer-sync participation
        weights (partial participation under a fault schedule).
        CONSUMES ``states``."""
        length = self.chunk if length is None else length
        end = start + length
        if self.trainer.sync.pins_round_boundary:
            boundary = (start // self.chunk + 1) * self.chunk
            if end > boundary:
                raise ValueError(
                    f"round [{start}, {end}) crosses the outer-sync boundary "
                    f"at step {boundary}; split windows at multiples of "
                    f"sync_every={self.chunk} (engine.run does this)"
                )
        do_sync = (end % self.chunk == 0) and self.trainer.sync.pins_round_boundary
        if weights is not None:
            weights = jnp.asarray(weights, jnp.float32)
            if weights.shape != (self.K, self.trainer.M):
                raise ValueError(
                    f"weights must be (K={self.K}, M={self.trainer.M}); "
                    f"got {weights.shape}"
                )
        states, metrics = self._round_fn(length, do_sync, weights is not None)(
            states, None, self._droot, self._dlogits, weights)
        return states, jax.device_get(metrics)

    def round_bounds(self, step: int, steps: int) -> Tuple[int, int]:
        end = min(steps, (step // self.chunk + 1) * self.chunk)
        nxt = min(steps, (end // self.chunk + 1) * self.chunk) - end
        return end, nxt

    def run(self, states, steps: int, start: int = 0):
        """Drive ``start..steps`` in H-aligned rounds for all K cells.
        Returns ``(states, metrics)`` with metrics as ``(K, steps - start)``
        host arrays."""
        collected = []
        step = start
        while step < steps:
            end, _ = self.round_bounds(step, steps)
            states, m = self.run_round(states, step, end - step)
            collected.append(m)
            step = end
        if not collected:
            return states, {}
        metrics = {
            k: np.concatenate(
                [np.atleast_2d(m[k]) for m in collected], axis=1)
            for k in collected[0]
        }
        return states, metrics

    def unstack(self, states) -> List[dict]:
        """Per-cell states (e.g. for the standard unbatched eval path)."""
        return [unstack_tree(states, k) for k in range(self.K)]
