"""Outer optimizer: SGD with Nesterov momentum on *outer gradients*.

Paper Algorithm 1: every H steps each replica's parameter delta
``Δ_m = θ^(t-H) - θ_m^(t)`` is averaged (an all-reduce over the replica/pod
axis) and treated as a gradient estimate for the global model.  The paper
uses SGD + Nesterov momentum 0.9 with a constant outer learning rate η.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def outer_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def abstract_outer_state(params):
    return jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)


def outer_step(global_params, delta, momentum, *, lr: float, mu: float = 0.9, nesterov: bool = True):
    """Returns (new_global_params, new_momentum).  delta = θ_prev - avg(θ_m)."""

    def upd(g, d, m):
        d32 = d.astype(jnp.float32)
        m_new = mu * m + d32
        step = d32 + mu * m_new if nesterov else m_new
        return (g.astype(jnp.float32) - lr * step).astype(g.dtype), m_new

    flat_g, treedef = jax.tree.flatten(global_params)
    flat_d = jax.tree.leaves(delta)
    flat_m = jax.tree.leaves(momentum)
    pairs = [upd(g, d, m) for g, d, m in zip(flat_g, flat_d, flat_m)]
    new_params = jax.tree.unflatten(treedef, [p for p, _ in pairs])
    new_mom = jax.tree.unflatten(treedef, [m for _, m in pairs])
    return new_params, new_mom
