"""Deterministic fault injection for chaos testing the DiLoCo runtime.

A :class:`FaultSchedule` is an immutable, fully explicit list of fault
events — replica crashes (with optional rejoin), straggler slowdowns,
transient I/O errors, and checkpoint-payload corruption.  Everything a
chaos run does is a pure function of ``(schedule, call order)``: the same
schedule replayed against the same run produces bit-identical faults,
which is what lets ``scripts/chaos_smoke.py`` assert that a crashed-and-
resumed run matches an uninterrupted run of the *same* schedule bitwise.

Round semantics (matching the train loop): outer round ``r`` covers inner
steps ``[r*H, (r+1)*H)``.  A replica with ``ReplicaCrash(at=2, rejoin=4)``
computes rounds 0–1, is dead (masked out of the outer average) for rounds
2–3, and participates again from round 4 — at which point the train loop
re-seeds it from the global params (``elastic.reseed_replicas``).

I/O faults are delivered through a process-global injector installed with
:func:`inject` — global rather than a contextvar because the checkpoint
writer runs on a background thread that does not inherit context.  Code
at I/O boundaries calls :func:`io_check(op)`; with no injector installed
it is a no-op, so production paths pay one global read.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

# I/O operation names checked by the runtime.  User schedules may name
# additional ops (e.g. a test-local boundary) — unknown ops simply never
# fire unless something calls io_check() with that name.
KNOWN_OPS = ("checkpoint_save", "checkpoint_restore", "ledger_append", "cell_run")


@dataclasses.dataclass(frozen=True)
class ReplicaCrash:
    """Replica ``replica`` is dead for rounds ``[at, rejoin)``.

    ``rejoin=-1`` means it never comes back.  While dead the replica is
    masked out of the outer average; at round ``rejoin`` it participates
    again after being re-seeded from the global params.
    """

    replica: int
    at: int
    rejoin: int = -1

    def dead(self, rnd: int) -> bool:
        return rnd >= self.at and (self.rejoin < 0 or rnd < self.rejoin)


@dataclasses.dataclass(frozen=True)
class Straggler:
    """Replica ``replica`` runs ``factor``x slower for rounds ``[start, stop)``."""

    replica: int
    start: int
    stop: int
    factor: float = 2.0


@dataclasses.dataclass(frozen=True)
class IOFault:
    """The first ``fails`` calls to ``io_check(op)`` raise a transient OSError."""

    op: str
    fails: int = 1


@dataclasses.dataclass(frozen=True)
class CorruptCheckpoint:
    """The checkpoint written at inner step ``step`` has its payload
    corrupted immediately after the (atomic) write publishes it —
    modelling bit rot / a torn write that the filesystem did not catch."""

    step: int


Event = Union[ReplicaCrash, Straggler, IOFault, CorruptCheckpoint]

_KINDS = {
    "crash": ReplicaCrash,
    "straggle": Straggler,
    "io": IOFault,
    "corrupt": CorruptCheckpoint,
}
_NAMES = {cls: kind for kind, cls in _KINDS.items()}


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A seeded, immutable set of fault events.

    ``seed`` tags the schedule (and seeds :meth:`random` generation); the
    events themselves are always explicit, so ``(seed, schedule)`` fully
    determines every chaos run.
    """

    events: Tuple[Event, ...] = ()
    seed: int = 0

    # -- round-level queries -------------------------------------------------
    def participation_mask(self, rnd: int, m: int) -> np.ndarray:
        """(m,) bool — which replicas participate in outer round ``rnd``."""
        mask = np.ones(m, dtype=bool)
        for ev in self.events:
            if isinstance(ev, ReplicaCrash) and 0 <= ev.replica < m and ev.dead(rnd):
                mask[ev.replica] = False
        return mask

    def rejoin_mask(self, rnd: int, m: int) -> np.ndarray:
        """(m,) bool — replicas participating in round ``rnd`` that were
        dead in round ``rnd - 1`` (empty at round 0): these must be
        re-seeded from the global params before the round starts."""
        if rnd <= 0:
            return np.zeros(m, dtype=bool)
        return self.participation_mask(rnd, m) & ~self.participation_mask(rnd - 1, m)

    def slowdowns(self, rnd: int, m: int) -> np.ndarray:
        """(m,) float — per-replica slowdown factor (>= 1) in round ``rnd``."""
        s = np.ones(m, dtype=np.float64)
        for ev in self.events:
            if (
                isinstance(ev, Straggler)
                and 0 <= ev.replica < m
                and ev.start <= rnd < ev.stop
            ):
                s[ev.replica] = max(s[ev.replica], float(ev.factor))
        return s

    def round_slowdown(self, rnd: int, m: int) -> float:
        """Round time multiplier: max slowdown over *participating*
        replicas (a dead replica gates nothing; everyone waits for the
        slowest survivor at the outer barrier)."""
        mask = self.participation_mask(rnd, m)
        if not mask.any():
            return 1.0
        return float(self.slowdowns(rnd, m)[mask].max())

    def mean_slowdown(self, rounds: int, m: int) -> float:
        """Mean of :meth:`round_slowdown` over rounds ``[0, rounds)`` —
        the aggregate straggler factor for ``wallclock.train_time``."""
        if rounds <= 0:
            return 1.0
        return float(
            np.mean([self.round_slowdown(r, m) for r in range(int(rounds))])
        )

    # -- I/O / corruption queries --------------------------------------------
    def io_fails(self) -> Dict[str, int]:
        """Total transient failures per I/O op (multiple events merge)."""
        out: Dict[str, int] = {}
        for ev in self.events:
            if isinstance(ev, IOFault):
                out[ev.op] = out.get(ev.op, 0) + int(ev.fails)
        return out

    def corrupt_steps(self) -> Tuple[int, ...]:
        return tuple(
            ev.step for ev in self.events if isinstance(ev, CorruptCheckpoint)
        )

    def has_replica_events(self) -> bool:
        return any(isinstance(ev, (ReplicaCrash, Straggler)) for ev in self.events)

    # -- spec string round-trip ----------------------------------------------
    def spec(self) -> str:
        """Serialize to the ``--faults`` spec grammar (``parse`` inverse)."""
        parts = []
        for ev in self.events:
            kv = ",".join(
                f"{f.name}={_fmt(getattr(ev, f.name))}"
                for f in dataclasses.fields(ev)
            )
            parts.append(f"{_NAMES[type(ev)]}:{kv}")
        if self.seed:
            parts.append(f"seed={self.seed}")
        return ";".join(parts)

    @classmethod
    def random(
        cls,
        seed: int,
        *,
        m: int,
        rounds: int,
        crash_rate: float = 0.3,
        straggle_rate: float = 0.3,
        io_rate: float = 0.5,
    ) -> "FaultSchedule":
        """Generate an explicit schedule from a seed — the events are
        materialized up front, so the run is reproducible from the
        returned schedule alone (``seed`` is only a generation recipe)."""
        rng = np.random.default_rng(seed)
        events: List[Event] = []
        for rep in range(m):
            if m > 1 and rng.random() < crash_rate:
                at = int(rng.integers(1, max(2, rounds)))
                rejoin = int(min(at + int(rng.integers(1, 3)), rounds))
                events.append(ReplicaCrash(replica=rep, at=at, rejoin=rejoin))
            if rng.random() < straggle_rate:
                start = int(rng.integers(0, max(1, rounds)))
                stop = int(min(start + int(rng.integers(1, 3)), rounds))
                if stop > start:
                    factor = float(np.round(1.5 + 2.0 * rng.random(), 2))
                    events.append(Straggler(rep, start, stop, factor))
        for op in ("checkpoint_save", "ledger_append"):
            if rng.random() < io_rate:
                events.append(IOFault(op=op, fails=int(rng.integers(1, 3))))
        return cls(events=tuple(events), seed=seed)


def _fmt(v) -> str:
    if isinstance(v, float):
        return repr(v)
    return str(v)


def parse(spec: str) -> FaultSchedule:
    """Parse a fault spec string into a :class:`FaultSchedule`.

    Grammar: ``;``-separated elements, each ``kind:key=value,...`` with
    kinds ``crash`` / ``straggle`` / ``io`` / ``corrupt``, plus an
    optional bare ``seed=N`` element.  Example::

        crash:replica=1,at=2,rejoin=4;straggle:replica=0,start=1,stop=3,factor=2.5;io:op=ledger_append,fails=2;corrupt:step=30;seed=7

    ``parse(s).spec()`` round-trips.
    """
    events: List[Event] = []
    seed = 0
    for raw in spec.split(";"):
        part = raw.strip()
        if not part:
            continue
        if part.startswith("seed="):
            seed = int(part[len("seed="):])
            continue
        kind, _, body = part.partition(":")
        cls = _KINDS.get(kind)
        if cls is None:
            raise ValueError(
                f"unknown fault kind {kind!r} in {part!r} "
                f"(expected one of {sorted(_KINDS)})"
            )
        kwargs = {}
        types = {f.name: f.type for f in dataclasses.fields(cls)}
        for item in filter(None, (s.strip() for s in body.split(","))):
            key, eq, val = item.partition("=")
            if not eq or key not in types:
                raise ValueError(f"bad option {item!r} for fault {kind!r}")
            kwargs[key] = float(val) if "float" in str(types[key]) else (
                val if "str" in str(types[key]) else int(val)
            )
        events.append(cls(**kwargs))
    return FaultSchedule(events=tuple(events), seed=seed)


class TransientIOError(OSError):
    """The injected transient I/O failure (an ``OSError`` so production
    retry paths treat it exactly like the real thing)."""


class FaultInjector:
    """Delivers a schedule's I/O faults and corruption events.

    Thread-safe: the checkpoint writer thread and the main thread both
    call :meth:`io_check`.  ``calls`` / ``raised`` expose per-op counters
    so tests can assert exactly which faults fired.
    """

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self._lock = threading.Lock()
        self._remaining = dict(schedule.io_fails())
        self.calls: Dict[str, int] = {}
        self.raised: Dict[str, int] = {}
        self.corrupted: List[Tuple[int, str]] = []

    def io_check(self, op: str) -> None:
        with self._lock:
            self.calls[op] = self.calls.get(op, 0) + 1
            if self._remaining.get(op, 0) > 0:
                self._remaining[op] -= 1
                self.raised[op] = self.raised.get(op, 0) + 1
                n = self.raised[op]
            else:
                return
        raise TransientIOError(f"injected transient {op} failure #{n}")

    def on_checkpoint_written(self, path: str, step: int) -> None:
        if step in self.schedule.corrupt_steps():
            corrupt_npz(os.path.join(path, "state.npz"))
            with self._lock:
                self.corrupted.append((step, path))


_ACTIVE: Optional[FaultInjector] = None
_ACTIVE_LOCK = threading.Lock()


@contextlib.contextmanager
def inject(schedule: Union[FaultSchedule, FaultInjector, str]):
    """Install a process-global injector for the ``with`` body.

    Accepts a schedule, a spec string, or a prebuilt injector (yielded
    either way, so callers can inspect its counters afterwards).
    """
    global _ACTIVE
    if isinstance(schedule, str):
        schedule = parse(schedule)
    injector = (
        schedule if isinstance(schedule, FaultInjector) else FaultInjector(schedule)
    )
    with _ACTIVE_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a fault injector is already active")
        _ACTIVE = injector
    try:
        yield injector
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE = None


def active() -> Optional[FaultInjector]:
    return _ACTIVE


def io_check(op: str) -> None:
    """Hook for I/O boundaries: raises the next scheduled transient
    ``OSError`` for ``op``, if any.  No-op when no injector is active."""
    inj = _ACTIVE
    if inj is not None:
        inj.io_check(op)


def on_checkpoint_written(path: str, step: int) -> None:
    """Hook the checkpointer calls after atomically publishing a
    checkpoint directory — applies any scheduled payload corruption."""
    inj = _ACTIVE
    if inj is not None:
        inj.on_checkpoint_written(path, step)


def corrupt_npz(path: str) -> None:
    """Corrupt an ``.npz`` payload *content-wise* while keeping it a
    loadable archive: every array is perturbed, so only manifest-v3
    content checksums (not zip CRCs alone) can prove it intact.  Used by
    the chaos smoke to model silent corruption."""
    with np.load(path) as z:
        arrays = {k: np.array(z[k]) for k in z.files}
    for k, v in arrays.items():
        if v.size:
            raw = np.frombuffer(v.tobytes(), dtype=np.uint8) ^ 0xFF
            arrays[k] = np.frombuffer(raw.tobytes(), dtype=v.dtype).reshape(v.shape)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
