"""Asynchronous DiLoCo (Liu et al. 2024b, the paper's §8 future work).

Replicas run their H inner steps WITHOUT a barrier; each applies its outer
gradient to the global model on arrival, discounted by staleness (how many
global versions landed since the replica last pulled):

    w(s) = discount^s,     θ ← OuterOpt(θ, w(s)·Δ_m)

With simultaneous arrivals and discount=1 this reduces exactly to classic
DiLoCo (tested).  The trainer below simulates heterogeneous replica speeds
in-process; on a real deployment each pod runs its own inner loop and the
global model lives behind the outer-update RPC.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import outer_opt
from repro.core.diloco import DiLoCo


@dataclasses.dataclass
class AsyncDiLoCo:
    trainer: DiLoCo
    staleness_discount: float = 0.5

    def init_state(self, key: jax.Array, dtype=jnp.float32) -> dict:
        st = self.trainer.init_state(key, dtype)
        st["global_version"] = jnp.zeros((), jnp.int32)
        # version of the global model each replica last pulled
        st["pulled_version"] = jnp.zeros((self.trainer.M,), jnp.int32)
        return st

    # -- per-replica inner work (no barrier) ------------------------------
    def replica_inner_step(self, state: dict, replica: int, batch_m: dict) -> dict:
        """One inner step for ONE replica (others untouched)."""
        params_m = jax.tree.map(lambda p: p[replica], state["inner_params"])
        opt_m = jax.tree.map(lambda o: o[replica], state["inner_opt"])
        new_p, new_o, _ = self.trainer._replica_step(
            params_m, opt_m, batch_m, state["step"], state["hparams"])
        return {
            **state,
            "inner_params": jax.tree.map(
                lambda full, new: full.at[replica].set(new.astype(full.dtype)),
                state["inner_params"], new_p,
            ),
            "inner_opt": jax.tree.map(
                lambda full, new: full.at[replica].set(new), state["inner_opt"], new_o
            ),
            "step": state["step"] + 1,
        }

    # -- arrival: apply one replica's outer gradient ----------------------
    def arrive(self, state: dict, replica: int) -> dict:
        """Replica `replica` reports: apply its staleness-discounted Δ and
        re-broadcast the fresh global model to it."""
        dcfg = self.trainer.dcfg
        gparams = state["global_params"]
        staleness = state["global_version"] - state["pulled_version"][replica]
        w = jnp.asarray(self.staleness_discount, jnp.float32) ** staleness.astype(jnp.float32)

        delta = jax.tree.map(
            lambda g, p: w * (g.astype(jnp.float32) - p[replica].astype(jnp.float32)),
            gparams, state["inner_params"],
        )
        hp = state["hparams"]
        new_global, new_mom = outer_opt.outer_step(
            gparams, delta, state["outer_m"],
            lr=hp["outer_lr"], mu=hp["outer_momentum"], nesterov=dcfg.nesterov,
        )
        new_inner = jax.tree.map(
            lambda full, g: full.at[replica].set(g.astype(full.dtype)),
            state["inner_params"], new_global,
        )
        version = state["global_version"] + 1
        return {
            **state,
            "global_params": new_global,
            "outer_m": new_mom,
            "inner_params": new_inner,
            "global_version": version,
            "pulled_version": state["pulled_version"].at[replica].set(version),
        }


def simulate(async_trainer: AsyncDiLoCo, data, *, steps: int, h: int,
             speeds: Optional[list] = None, seed: int = 0):
    """In-process simulation: replica m runs `speeds[m]` inner steps per tick;
    it reports (arrives) every time it accumulates h inner steps.
    Returns (state, losses)."""
    tr = async_trainer.trainer
    m_total = tr.M
    speeds = speeds or [1] * m_total
    state = async_trainer.init_state(jax.random.PRNGKey(seed))
    inner = jax.jit(async_trainer.replica_inner_step, static_argnums=1)
    arrive = jax.jit(async_trainer.arrive, static_argnums=1)
    since_sync = [0] * m_total
    losses = []
    t = 0
    for tick in range(steps):
        for m in range(m_total):
            for _ in range(speeds[m]):
                batch = data.batch(t, m, m_total, 2)
                state = inner(state, m, batch)
                t += 1
                since_sync[m] += 1
                if since_sync[m] >= h:
                    state = arrive(state, m)
                    since_sync[m] = 0
        loss = tr.eval_step(state, data.batch(90_000 + tick, 0, 1, 8, eval=True))
        losses.append(float(loss))
    return state, losses
