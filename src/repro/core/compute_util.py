"""Compute-utilization simulator (paper §5.1, Table 6 / Figure 10).

CU = compute_time / (compute_time + comm_time).  For a model of N params
synchronized every H steps over a network of bandwidth W:

    comm_per_step = 2·N·bits/W · (1 − 1/R) / H        (amortized outer sync)
    CU(W) = step_time / (step_time + comm_per_step)

``required_bandwidth`` inverts this: the minimum W reaching a CU target.

Calibration note: the paper's published Table-6 values are consistent with a
FULL-DUPLEX ring (send/receive overlap, so wall time ≈ N·bits·(1−1/R)/W
without the half-duplex factor 2 of Appendix A) at ~8 bits/param — e.g.
Llama3-405B @ CU=50%: ours 122.6 Gbit/s vs paper 126.5 (their simulator
snaps to a geometric grid).  ``repro.core.wallclock`` keeps the Appendix-A
half-duplex formula verbatim; this module matches Table 6.
"""
from __future__ import annotations

import numpy as np

# paper Table 6 rows: (name, params, step_time_s)
TABLE6_MODELS = (
    ("Chinchilla-10B", 10e9, 0.8),
    ("Llama3-405B", 405e9, 26.0),
    ("DeepSeek-V3-671B", 671e9, 20.0),
)

CU_TARGETS = (0.50, 0.80, 0.90, 0.95, 0.99)
H_VALUES = (1, 10, 50, 100, 300)


def comm_time_per_step(n_params, bandwidth_bps, sync_every=1, r_nodes=64, bits_per_param=8):
    wire_bits = n_params * bits_per_param * (1.0 - 1.0 / r_nodes)  # full-duplex ring
    return wire_bits / bandwidth_bps / sync_every


def compute_utilization(n_params, step_time, bandwidth_bps, sync_every=1, **kw):
    comm = comm_time_per_step(n_params, bandwidth_bps, sync_every, **kw)
    return step_time / (step_time + comm)


def required_bandwidth(n_params, step_time, cu_target, sync_every=1,
                       r_nodes=64, bits_per_param=8):
    """Minimum bandwidth (bits/s) to reach `cu_target`."""
    comm_budget = step_time * (1.0 - cu_target) / cu_target
    wire_bits = n_params * bits_per_param * (1.0 - 1.0 / r_nodes)  # full-duplex ring
    return wire_bits / (comm_budget * sync_every)


def bandwidth_grid(lo=0.1e9, hi=1000e9, steps=50):
    return np.geomspace(lo, hi, steps)


def snap_to_grid(w, grid=None):
    """Snap bandwidth(s) to the NEAREST grid point in log space.

    The grid is geometric (Table-6 calibration note: the paper's simulator
    snaps to a ~1.21x-per-step geometric grid), so "nearest" must be
    measured in log space — midpoints between grid points are geometric
    means, not arithmetic ones.  Out-of-range inputs clamp to the grid
    ends (the old searchsorted version snapped interior values upward and
    silently truncated values above the max).
    """
    g = np.asarray(bandwidth_grid() if grid is None else grid, float)
    w = np.asarray(w, float)
    if np.any(w <= 0):
        raise ValueError(f"bandwidth must be positive, got {w}")
    idx = np.argmin(np.abs(np.log(g) - np.log(w)[..., None]), axis=-1)
    out = g[idx]
    return float(out) if np.isscalar(idx) or out.ndim == 0 else out


def table6(bits_per_param=8, compression_ratio=1.0) -> list:
    """Reproduce the paper's Table 6 structure.

    ``compression_ratio``: beyond-paper int8 outer-Δ compression divides the
    outer payload (e.g. 2.0 for int8-vs-bf16).
    """
    rows = []
    for name, n, step in TABLE6_MODELS:
        for algo, h in [("Data-Parallel", 1)] + [("DiLoCo", h) for h in H_VALUES]:
            bw = [
                required_bandwidth(n / compression_ratio if (algo == "DiLoCo" and h > 1) else n,
                                   step, cu, sync_every=h,
                                   bits_per_param=bits_per_param) / 1e9
                for cu in CU_TARGETS
            ]
            rows.append({"model": name, "size": n, "step_time": step,
                         "method": f"{algo}, H={h}" if algo == "DiLoCo" else algo,
                         "gbits": bw})
    return rows
