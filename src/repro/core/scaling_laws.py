"""Scaling-law fitting (paper §6).

* independent power laws  L(N) ≈ A·N^α           (Tables 7-9)
* joint power laws        f(N,M) ≈ A·N^α·M^β     (Table 10)
* quadratic-in-log2(B) interpolation of the optimal batch size (§6.1)
* four parametric forms for L(N,M) fit with Huber-on-log loss and
  multi-restart BFGS (§6.5, Table 13)
* residual metric res(y, ŷ) = |log y − log ŷ|     (§6.3)

No scipy dependency: BFGS comes from ``jax.scipy.optimize.minimize``.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Power-law fits (closed-form in log space)
# ---------------------------------------------------------------------------


def fit_power_law(x: Sequence[float], y: Sequence[float]) -> Tuple[float, float]:
    """L(x) ≈ A·x^α via linear regression on logs. Returns (A, alpha)."""
    lx = np.log(np.asarray(x, float))
    ly = np.log(np.asarray(y, float))
    alpha, loga = np.polyfit(lx, ly, 1)
    return float(np.exp(loga)), float(alpha)


def predict_power_law(A: float, alpha: float, x) -> np.ndarray:
    return A * np.asarray(x, float) ** alpha


def fit_joint_power_law(n, m, y) -> Tuple[float, float, float]:
    """f(N,M) ≈ A·N^α·M^β. Returns (A, alpha, beta)."""
    ln = np.log(np.asarray(n, float))
    lm = np.log(np.asarray(m, float))
    ly = np.log(np.asarray(y, float))
    X = np.stack([np.ones_like(ln), ln, lm], axis=1)
    coef, *_ = np.linalg.lstsq(X, ly, rcond=None)
    return float(np.exp(coef[0])), float(coef[1]), float(coef[2])


def predict_joint(A, alpha, beta, n, m) -> np.ndarray:
    return A * np.asarray(n, float) ** alpha * np.asarray(m, float) ** beta


def residual(y, y_hat) -> float:
    """Paper §6.3: res = |log y − log ŷ| (mean over entries)."""
    return float(np.mean(np.abs(np.log(np.asarray(y, float)) - np.log(np.asarray(y_hat, float)))))


# ---------------------------------------------------------------------------
# Optimal batch size via quadratic-in-log2 interpolation (§6.1)
# ---------------------------------------------------------------------------


def quadratic_log2_optimum(batch_sizes, losses) -> float:
    """Fit loss ~ quadratic in log2(B); return argmin B (clipped to range)."""
    lb = np.log2(np.asarray(batch_sizes, float))
    ly = np.asarray(losses, float)
    c2, c1, _ = np.polyfit(lb, ly, 2)
    if c2 <= 0:  # degenerate: no interior minimum
        return float(batch_sizes[int(np.argmin(ly))])
    opt = -c1 / (2 * c2)
    opt = np.clip(opt, lb.min(), lb.max())
    return float(2.0 ** opt)


# ---------------------------------------------------------------------------
# Parametric forms for L(N, M) (§6.5)
# ---------------------------------------------------------------------------
# Parameterized for positivity: A = exp(a), C = exp(c), B = exp(b).
# N is normalized by N0 inside the forms (conditioning; the paper-facing
# coefficients can be recovered analytically if needed).

N0 = 1e8


def _form1(p, n, m):  # A N^a M^b
    return jnp.exp(p[0]) * (n / N0) ** p[1] * m ** p[2]


def _form2(p, n, m):  # A N^a M^b + C
    return jnp.exp(p[0]) * (n / N0) ** p[1] * m ** p[2] + jnp.exp(p[3])


def _form3(p, n, m):  # A N^(a + b M) + C
    return jnp.exp(p[0]) * (n / N0) ** (p[1] + p[2] * m) + jnp.exp(p[3])


def _form4(p, n, m):  # A N^a + B M^b + C
    return jnp.exp(p[0]) * (n / N0) ** p[1] + jnp.exp(p[2]) * m ** p[3] + jnp.exp(p[4])


PARAMETRIC_FORMS: Dict[str, Tuple[Callable, int]] = {
    "AN^aM^b": (_form1, 3),
    "AN^aM^b+C": (_form2, 4),
    "AN^(a+bM)+C": (_form3, 4),
    "AN^a+BM^b+C": (_form4, 5),
}


def _huber(x, delta=1e-3):
    ax = jnp.abs(x)
    return jnp.where(ax <= delta, 0.5 * x * x, delta * (ax - 0.5 * delta))


def fit_parametric(
    form: str,
    n,
    m,
    y,
    *,
    restarts: int = 64,
    delta: float = 1e-3,
    seed: int = 0,
    holdout_mask=None,
):
    """Fit one parametric form with Huber-on-log loss, multi-restart BFGS.

    ``holdout_mask``: boolean array — True entries are EXCLUDED from the fit
    and used for restart selection (paper §6.5 holds out the largest scale).
    Returns (params, train_obj, holdout_residual).
    """
    fn, n_params = PARAMETRIC_FORMS[form]
    n = jnp.asarray(n, jnp.float32)
    m = jnp.asarray(m, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    if holdout_mask is None:
        holdout_mask = jnp.zeros(y.shape, bool)
    holdout_mask = jnp.asarray(holdout_mask)
    fit_w = (~holdout_mask).astype(jnp.float32)

    def objective(p):
        pred = fn(p, n, m)
        r = jnp.log(jnp.maximum(pred, 1e-9)) - jnp.log(y)
        return jnp.sum(_huber(r, delta) * fit_w)

    # compact Adam minimizer (jax.scipy.optimize was removed in jax 0.8);
    # jitted + vmapped over all restarts at once.
    def solve(p0, steps=4000, lr=0.03):
        vg = jax.value_and_grad(objective)

        def body(carry, _):
            p, mom, vel, t = carry
            f, g = vg(p)
            mom = 0.9 * mom + 0.1 * g
            vel = 0.999 * vel + 0.001 * g * g
            t = t + 1
            mhat = mom / (1 - 0.9 ** t)
            vhat = vel / (1 - 0.999 ** t)
            p = p - lr * mhat / (jnp.sqrt(vhat) + 1e-9)
            return (p, mom, vel, t), None

        init = (p0, jnp.zeros_like(p0), jnp.zeros_like(p0), jnp.zeros((), jnp.float32))
        (p, _, _, _), _ = jax.lax.scan(body, init, None, length=steps)
        return p, objective(p)

    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, restarts)
    scales = jnp.asarray([1.0] + [0.3] * (n_params - 1))
    p0s = jax.vmap(lambda k: jax.random.normal(k, (n_params,)) * scales)(keys)
    p0s = p0s.at[:, 0].add(jnp.log(y.mean()))
    px, fx = jax.jit(jax.vmap(solve))(p0s)

    best = None
    for i in range(restarts):
        if not bool(jnp.isfinite(fx[i])):
            continue
        pred = fn(px[i], n, m)
        if holdout_mask.any():
            sel = float(jnp.sum(jnp.abs(jnp.log(pred) - jnp.log(y)) * holdout_mask)
                        / jnp.maximum(holdout_mask.sum(), 1))
        else:
            sel = float(fx[i])
        if not np.isfinite(sel):
            continue
        if best is None or sel < best[2]:
            best = (np.asarray(px[i]), float(fx[i]), sel)
    assert best is not None, "all restarts diverged"
    return best


def parametric_predict(form: str, params, n, m):
    fn, _ = PARAMETRIC_FORMS[form]
    return np.asarray(fn(jnp.asarray(params), jnp.asarray(n, jnp.float32),
                         jnp.asarray(m, jnp.float32)))


# ---------------------------------------------------------------------------
# Paper data fixture (Tables 4/7): used to validate the fitting machinery
# against the paper's own published numbers.
# ---------------------------------------------------------------------------

PAPER_MODEL_SIZES = np.array([35e6, 90e6, 180e6, 335e6, 550e6, 1.3e9, 2.4e9])

PAPER_TABLE4_LOSS = {
    # algorithm -> losses at the 7 tuned scales
    "dp": [3.485, 3.167, 2.950, 2.784, 2.653, 2.460, 2.326],
    "diloco_m1": [3.482, 3.162, 2.943, 2.777, 2.645, 2.451, 2.317],
    "diloco_m2": [3.508, 3.182, 2.957, 2.788, 2.657, 2.464, 2.323],
    "diloco_m4": [3.554, 3.213, 2.981, 2.808, 2.673, 2.472, 2.332],
    "diloco_m8": [3.621, 3.265, 3.019, 2.841, 2.698, 2.493, 2.351],
}

PAPER_TABLE7_FITS = {
    "dp": (18.129, -0.0953),
    "diloco_m1": (18.363, -0.0961),
    "diloco_m2": (18.768, -0.0969),
    "diloco_m4": (19.762, -0.0992),
    "diloco_m8": (21.051, -0.1018),
}

PAPER_TABLE10_JOINT = {"L": (19.226, -0.0985, 0.0116)}
