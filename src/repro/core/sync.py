"""Pluggable outer-sync strategies: one abstraction for every sync variant.

The paper's central variable is *how and how often* replicas synchronize
(Algorithm 1's outer step), and the follow-on literature is an explosion of
sync variants — quantized outer gradients, fragment-wise streaming
(Streaming DiLoCo), gossip averaging (NoLoCo), ...  A ``SyncStrategy`` is
that variant as a first-class object.  It owns everything a variant
defines:

* **extra state leaves** — ``extra_state`` / ``abstract_extra_state`` /
  ``extra_state_partition_specs`` (e.g. the int8/int4 error-feedback
  residuals under the ``"ef"`` key);
* **the in-graph transform** — ``apply(trainer, state, weights)`` for
  strategies that sync once per H-step round, ``apply_fragment`` +
  ``fragment_due`` for fragment-wise (streaming-style) strategies whose
  syncs ride *inside* the compiled round's scan body;
* **scheduling capabilities** — ``uses_outer_opt`` (False only for pure
  Data-Parallel), ``num_fragments``, and the derived
  ``pins_round_boundary`` flag both engines consult when deciding whether
  a round window may cross an H boundary;
* **comm accounting** — ``outer_payload_bytes(n_params)`` (bytes each
  participant transmits per outer-sync event) and
  ``sync_events_per_round``, which feed ``repro.core.wallclock`` and the
  Table-6 CU model instead of hardcoded per-mode ratios;
* **identity** — the checkpoint-manifest ``tag`` (back-compat: the full
  -precision strategy keeps the historical ``"none"`` tag), the
  contribution to ``repro.core.diloco.static_signature`` (so jitcache /
  cell-batch sharing keys stay exact), and the config-fingerprint
  canonicalization that keeps pre-strategy checkpoints restoring without
  a drift warning.

Strategies register by name::

    @sync.register("int4")
    @dataclasses.dataclass(frozen=True)
    class Int4BlockSync(sync.QuantizedOuterSync):
        ...

and are selected either through the new config field
(``DiLoCoConfig(sync="int8")``, CLI ``--sync int8`` /
``--sync streaming:fragments=4``) or through the legacy flags
(``data_parallel`` / ``compression`` / ``streaming_fragments``), which
``resolve`` maps onto the same registered strategies (with a
``DeprecationWarning`` for the compression/streaming flags).  Both paths
produce identical strategies, signatures, fingerprints, and — since the
strategy *is* the sync code now — bitwise-identical trajectories.

Options in a spec string are ``name:key=value,key=value`` with int/float/
bool coercion; ``SyncStrategy.spec()`` is the canonical inverse.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, ClassVar, Dict, List, Optional, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core import compression, outer_opt, streaming
from repro.core.wallclock import BITS_PER_PARAM

_REGISTRY: Dict[str, Type["SyncStrategy"]] = {}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def register(name: str) -> Callable[[type], type]:
    """Class decorator: register a strategy under ``name``.

    The decorated class gets ``cls.name = name`` and — unless it defines its
    own — ``cls.tag = name`` (the checkpoint-manifest tag).  Registering an
    already-taken name raises (collisions would silently shadow a strategy).
    """

    def deco(cls: type) -> type:
        if name in _REGISTRY:
            raise ValueError(
                f"sync strategy {name!r} is already registered "
                f"(to {_REGISTRY[name].__qualname__}); pick a new name or "
                "unregister() the old one first"
            )
        cls.name = name
        if "tag" not in cls.__dict__:
            cls.tag = name
        _REGISTRY[name] = cls
        return cls

    return deco


def unregister(name: str) -> None:
    """Remove a registered strategy (tests / plugin teardown)."""
    _REGISTRY.pop(name, None)


def names() -> List[str]:
    return sorted(_REGISTRY)


def get(name: str, **opts) -> "SyncStrategy":
    """Instantiate the strategy registered under ``name`` with ``opts``."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown sync strategy {name!r}; registered strategies: "
            f"{', '.join(names())}"
        ) from None
    try:
        return cls(**opts)
    except TypeError as e:
        valid = ", ".join(f.name for f in dataclasses.fields(cls)) or "(none)"
        raise ValueError(
            f"bad options for sync strategy {name!r}: {e}; "
            f"valid options: {valid}"
        ) from None


def from_tag(tag: str) -> Type["SyncStrategy"]:
    """Strategy CLASS for a checkpoint-manifest ``sync_mode`` tag (options
    are not recorded in manifests, so the class is the round-trip unit).
    Legacy manifests use ``"none"`` for full-precision DiLoCo — that alias
    is permanent (the tag is written to disk)."""
    for cls in _REGISTRY.values():
        if cls.tag == tag:
            return cls
    raise KeyError(
        f"no registered sync strategy for manifest tag {tag!r}; known tags: "
        f"{', '.join(sorted(c.tag for c in _REGISTRY.values()))}"
    )


def parse_spec(spec: str) -> "SyncStrategy":
    """``"name"`` or ``"name:key=value,key=value"`` -> strategy instance."""
    name, _, rest = spec.partition(":")
    opts = {}
    if rest:
        for item in rest.split(","):
            key, eq, val = item.partition("=")
            if not eq:
                raise ValueError(
                    f"malformed sync option {item!r} in {spec!r} "
                    "(expected key=value)"
                )
            opts[key.strip()] = _coerce(val.strip())
    return get(name.strip(), **opts)


def _coerce(v: str):
    low = v.lower()
    if low in ("true", "false"):
        return low == "true"
    for typ in (int, float):
        try:
            return typ(v)
        except ValueError:
            pass
    return v


def resolve(dcfg) -> "SyncStrategy":
    """The strategy for a ``DiLoCoConfig`` — from ``dcfg.sync`` when set,
    otherwise from the legacy flag triple (deprecation shim: old configs,
    ledgers, and checkpoints keep resolving to the same strategies)."""
    if getattr(dcfg, "sync", ""):
        strat = parse_spec(dcfg.sync)
    elif dcfg.data_parallel:
        strat = get("dp")
    elif dcfg.compression != "none":
        warnings.warn(
            f"DiLoCoConfig(compression={dcfg.compression!r}) is deprecated; "
            f"use DiLoCoConfig(sync={dcfg.compression!r})",
            DeprecationWarning, stacklevel=3,
        )
        strat = get(dcfg.compression, error_feedback=dcfg.error_feedback)
    elif dcfg.streaming_fragments > 0:
        warnings.warn(
            f"DiLoCoConfig(streaming_fragments={dcfg.streaming_fragments}) "
            f"is deprecated; use DiLoCoConfig(sync="
            f"'streaming:fragments={dcfg.streaming_fragments}')",
            DeprecationWarning, stacklevel=3,
        )
        strat = get("streaming", fragments=dcfg.streaming_fragments)
    else:
        strat = get("full")
    strat.validate(dcfg)
    return strat


def describe() -> str:
    """Human-readable table of the registered strategies (``--list-syncs``)."""
    rows = [("name", "tag", "extra state", "payload B/param", "events/round",
             "round-pinned")]
    for name in names():
        cls = _REGISTRY[name]
        try:
            s = cls()
            detail = (f"{s.outer_payload_bytes(1.0):g}",
                      str(s.sync_events_per_round),
                      "yes" if s.pins_round_boundary else "no")
        except Exception:  # strategy with required options: still list it
            detail = ("?", "?", "?")
        rows.append((
            name, cls.tag, ",".join(cls.extra_state_keys) or "-", *detail,
        ))
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)) for r in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Shared transform pieces
# ---------------------------------------------------------------------------


def _normalized_weights(weights: Optional[jax.Array]) -> Optional[jax.Array]:
    """Optional (M,) participation weights -> normalized, or None (uniform)."""
    if weights is None:
        return None
    return weights / jnp.maximum(weights.sum(), 1e-9)


def outer_update(trainer, state: dict, delta, updates: Optional[dict] = None) -> dict:
    """Nesterov outer step on ``delta`` + broadcast of the fresh global
    model to every replica — the tail every full-round strategy shares."""
    hp = state["hparams"]
    new_global, new_mom = outer_opt.outer_step(
        state["global_params"], delta, state["outer_m"],
        lr=hp["outer_lr"], mu=hp["outer_momentum"],
        nesterov=trainer.dcfg.nesterov,
    )
    new_inner = jax.tree.map(
        lambda g, p: jnp.broadcast_to(g[None].astype(p.dtype), p.shape),
        new_global, state["inner_params"],
    )
    new_inner = trainer._constrain(new_inner)
    out = {
        **state,
        "inner_params": new_inner,
        "global_params": new_global,
        "outer_m": new_mom,
    }
    if updates:
        out.update(updates)
    return out


def _full_precision_apply(trainer, state: dict, weights=None) -> dict:
    """Full-precision outer sync (the paper's Algorithm 1 outer step)."""
    gparams = state["global_params"]
    inner = state["inner_params"]
    w = _normalized_weights(weights)
    if w is None:
        # mean_m(θ_g - θ_m) = θ_g - mean_m(θ_m): the replica mean folds
        # into one fp32-accumulated reduction — the (M, ...) fp32 delta
        # stack is never materialized, so peak memory does not scale
        # with M in fp32
        delta = jax.tree.map(
            lambda g, p: g.astype(jnp.float32)
            - jnp.mean(p, axis=0, dtype=jnp.float32),
            gparams, inner,
        )
    else:
        # Σ_m w_m (θ_g - θ_m) = θ_g - Σ_m w_m θ_m for normalized w
        delta = jax.tree.map(
            lambda g, p: g.astype(jnp.float32)
            - jnp.einsum("m,m...->...", w, p, preferred_element_type=jnp.float32),
            gparams, inner,
        )
    return outer_update(trainer, state, delta)


# ---------------------------------------------------------------------------
# Protocol
# ---------------------------------------------------------------------------


class SyncStrategy:
    """Base protocol; concrete strategies are small frozen dataclasses whose
    fields are the strategy's *options* (everything structural — anything
    that changes the traced computation — must be a field so it lands in
    ``static_signature``)."""

    # set by @register
    name: ClassVar[str] = "?"
    tag: ClassVar[str] = "?"
    # capabilities
    uses_outer_opt: ClassVar[bool] = True   # False only for pure DP
    extra_state_keys: ClassVar[Tuple[str, ...]] = ()

    # ---- scheduling capabilities ----------------------------------------
    @property
    def num_fragments(self) -> int:
        """>0 for fragment-wise strategies whose syncs ride mid-round in the
        compiled scan body (streaming-style); 0 for everything else."""
        return 0

    @property
    def pins_round_boundary(self) -> bool:
        """True when the strategy performs exactly ONE outer sync at the end
        of each H-aligned round.  Both engines consult this single flag: a
        round window must then never cross an interior H boundary (it would
        silently skip that boundary's sync), and ``do_sync`` fires only on
        boundaries.  DP (no sync) and fragment-wise strategies (syncs
        inside the scan) leave windows free."""
        return self.uses_outer_opt and self.num_fragments == 0

    @property
    def sync_events_per_round(self) -> int:
        """Cross-replica collectives per H-step round (comm accounting)."""
        if not self.uses_outer_opt:
            return 0
        return max(1, self.num_fragments)

    # ---- extra state ----------------------------------------------------
    def extra_state(self, trainer, gparams) -> dict:
        """Strategy-owned state leaves merged into the trainer state (e.g.
        error-feedback residuals).  Keys must match ``extra_state_keys``.
        Elastic resize (``repro.core.elastic.resize_replicas``) treats these
        as per-replica param-shaped trees — ``(M, *param.shape)`` leaves,
        zero-filled for fresh replicas; strategies with differently-shaped
        extra state also need their own resize handling."""
        return {}

    def abstract_extra_state(self, trainer, gparams) -> dict:
        return {}

    def extra_state_partition_specs(self, trainer, pspec) -> dict:
        """PartitionSpecs for the extra leaves; ``pspec`` is the trainer's
        ``model.param_partition_specs`` callable."""
        return {}

    # ---- transforms ------------------------------------------------------
    def apply(self, trainer, state: dict, weights=None) -> dict:
        """The in-graph outer sync for one full round (traceable; embedded
        at the end of the compiled superstep and behind ``lax.cond`` in the
        fused ``train_step``)."""
        raise NotImplementedError

    def apply_fragment(self, trainer, state: dict, fragment: int) -> dict:
        raise NotImplementedError(
            f"sync strategy {self.name!r} has no fragment-wise sync"
        )

    def fragment_due(self, step, fragment: int, sync_every: int):
        """Traceable predicate: does ``fragment`` sync at (1-based) ``step``?"""
        raise NotImplementedError(
            f"sync strategy {self.name!r} has no fragment schedule"
        )

    def fragments_due(self, step: int, sync_every: int) -> List[int]:
        """Host-side schedule (the per-step loop's Python scheduler)."""
        return []

    def fragment_applier(self, trainer) -> Callable:
        """Traceable ``(state, fragment) -> state`` with any per-trace
        precomputation (static partitions) done once, for embedding inside
        a compiled round's scan body."""
        raise NotImplementedError(
            f"sync strategy {self.name!r} has no fragment-wise sync"
        )

    def jitted_fragment(self, trainer, fragment: int):
        """Cached, donated, compiled per-fragment sync (per-step engine)."""
        raise NotImplementedError(
            f"sync strategy {self.name!r} has no fragment-wise sync"
        )

    def with_num_fragments(self, fragments: int) -> "SyncStrategy":
        """The sweep grid's fragment-count axis applied to this strategy.
        Fragment-wise strategies return a copy with that count (whatever
        their option is called); everything else ignores the axis."""
        return self

    # ---- comm accounting -------------------------------------------------
    def outer_payload_bytes(self, n_params: float) -> float:
        """Bytes each participant transmits per outer-sync EVENT (the
        cross-datacenter all-reduce payload).  Baseline: bf16 deltas."""
        return n_params * BITS_PER_PARAM / 8.0

    @property
    def compression_ratio(self) -> float:
        """Full-round payload reduction vs full-precision bf16 (Table-6 CU
        model input): 1.0 for full/streaming (same total bytes), 2.0 for
        int8, 4.0 for int4, ..."""
        events = self.sync_events_per_round
        if events <= 0:
            return 1.0
        total = self.outer_payload_bytes(1.0) * events
        base = BITS_PER_PARAM / 8.0
        return base / total if total > 0 else 1.0

    # ---- identity --------------------------------------------------------
    def static_signature(self) -> tuple:
        """The strategy's contribution to ``diloco.static_signature``: the
        registered name plus every option field.  Two trainers whose
        strategies differ here must never share executables."""
        return (self.name,) + tuple(
            (f.name, getattr(self, f.name)) for f in dataclasses.fields(self)
        )

    def spec(self) -> str:
        """Canonical ``name[:key=value,...]`` string (non-default options
        only) — ``parse_spec(s.spec())`` round-trips."""
        opts = {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if getattr(self, f.name) != f.default
        }
        if not opts:
            return self.name
        return self.name + ":" + ",".join(
            f"{k}={v}" for k, v in sorted(opts.items())
        )

    def legacy_flags(self) -> Optional[dict]:
        """The pre-strategy ``DiLoCoConfig`` flag values this strategy is
        equivalent to, or None if it has no legacy spelling.  Used to keep
        config fingerprints identical across the flag->strategy migration
        (old checkpoints must not warn about config drift)."""
        return None

    def fingerprint_fields(self, dcfg) -> dict:
        """The ``diloco`` section of the checkpoint config fingerprint,
        canonicalized: legacy-expressible strategies digest exactly like
        the pre-strategy flag configs; new strategies key on their spec."""
        d = dataclasses.asdict(dcfg)
        d.pop("num_replicas", None)  # elastic M -> M' restore is supported
        d.pop("sync", None)
        legacy = self.legacy_flags()
        if legacy is None:
            d.update(data_parallel=False, compression="none",
                     streaming_fragments=0)
            d["sync"] = self.spec()
        else:
            d.update(legacy)
        return d

    def validate(self, dcfg) -> None:
        """Raise on strategy/config combinations that cannot run."""


# ---------------------------------------------------------------------------
# Built-in strategies
# ---------------------------------------------------------------------------


@register("dp")
@dataclasses.dataclass(frozen=True)
class DataParallelSync(SyncStrategy):
    """Pure Data-Parallel: no outer optimizer, no outer sync (the per-step
    gradient all-reduce is the only collective — billed per step by the
    wall-clock model, not here)."""

    uses_outer_opt: ClassVar[bool] = False

    def apply(self, trainer, state, weights=None):
        return state

    def outer_payload_bytes(self, n_params: float) -> float:
        return 0.0

    def legacy_flags(self):
        return {"data_parallel": True, "compression": "none",
                "streaming_fragments": 0}

    def validate(self, dcfg) -> None:
        if dcfg.num_replicas != 1:
            raise ValueError(
                "Data-Parallel is the M=1, no-outer-opt case "
                f"(got num_replicas={dcfg.num_replicas})"
            )


@register("full")
@dataclasses.dataclass(frozen=True)
class FullSync(SyncStrategy):
    """Paper Algorithm 1: full-precision outer-gradient average + Nesterov
    outer step every H steps."""

    tag: ClassVar[str] = "none"  # historical manifest tag; permanent

    def apply(self, trainer, state, weights=None):
        return _full_precision_apply(trainer, state, weights)

    def legacy_flags(self):
        return {"data_parallel": False, "compression": "none",
                "streaming_fragments": 0}


class QuantizedOuterSync(SyncStrategy):
    """Shared machinery for quantize-the-outer-Δ strategies: per-replica
    quantization with optional error feedback carried in the ``"ef"`` state
    leaf.  Subclasses define ``quantize_leaf`` (fp32 leaf -> dequantized
    fp32 leaf, i.e. what the all-reduce payload decodes to) and
    ``outer_payload_bytes``."""

    extra_state_keys: ClassVar[Tuple[str, ...]] = ("ef",)
    # subclasses are dataclasses with an ``error_feedback: bool = True`` field

    def quantize_leaf(self, v: jax.Array) -> jax.Array:
        raise NotImplementedError

    def extra_state(self, trainer, gparams) -> dict:
        if not self.error_feedback:
            return {}
        return {"ef": compression.init_error_feedback(gparams, trainer.M)}

    def abstract_extra_state(self, trainer, gparams) -> dict:
        if not self.error_feedback:
            return {}
        return {"ef": compression.abstract_error_feedback(gparams, trainer.M)}

    def extra_state_partition_specs(self, trainer, pspec) -> dict:
        if not self.error_feedback:
            return {}
        return {"ef": pspec(extra_leading=("replica",))}

    def apply(self, trainer, state, weights=None):
        gparams = state["global_params"]
        inner = state["inner_params"]
        w = _normalized_weights(weights)
        # per-replica Δ_m stacks are inherent here: each replica quantizes
        # (and keeps error feedback for) its own transmission
        delta_m = jax.tree.map(
            lambda g, p: g[None].astype(jnp.float32) - p.astype(jnp.float32),
            gparams, inner,
        )
        ef = state.get("ef") if self.error_feedback else None
        delta_m, new_ef = compression.compress_tree(
            delta_m, ef, quantize=self.quantize_leaf
        )
        if w is None:
            delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), delta_m)
        else:
            delta = jax.tree.map(
                lambda d: jnp.tensordot(w, d.astype(jnp.float32), axes=1),
                delta_m,
            )
        updates = {"ef": new_ef} if self.error_feedback else None
        return outer_update(trainer, state, delta, updates)


@register("int8")
@dataclasses.dataclass(frozen=True)
class Int8Sync(QuantizedOuterSync):
    """int8 symmetric per-tensor quantization of the outer deltas with error
    feedback — 2x the cross-DC bytes of bf16 (the per-tensor fp32 scale is
    negligible against the 1 byte/param payload)."""

    error_feedback: bool = True

    def quantize_leaf(self, v: jax.Array) -> jax.Array:
        q, s = compression.int8_quantize(v)
        return compression.int8_dequantize(q, s)

    def outer_payload_bytes(self, n_params: float) -> float:
        return float(n_params)  # 1 byte/param

    def legacy_flags(self):
        return {"data_parallel": False, "compression": "int8",
                "streaming_fragments": 0, "error_feedback": self.error_feedback}


@register("streaming")
@dataclasses.dataclass(frozen=True)
class StreamingSync(SyncStrategy):
    """Streaming DiLoCo (Douillard et al. 2025): parameters split into P
    fragments, fragment p syncing every H steps at offset p*(H/P) — the
    syncs ride inside the compiled round's scan body.  Total round bytes
    are unchanged (paper Appendix A); the per-event payload drops by P."""

    fragments: int = 2

    @property
    def num_fragments(self) -> int:
        return self.fragments

    def apply(self, trainer, state, weights=None):
        # "sync everything now": the fused train_step / dry-run treats an H
        # boundary as one full-precision sync of every fragment at once
        return _full_precision_apply(trainer, state, weights)

    def apply_fragment(self, trainer, state, fragment: int):
        return self.fragment_applier(trainer)(state, fragment)

    def fragment_due(self, step, fragment: int, sync_every: int):
        return streaming.is_due(step, fragment, self.fragments, sync_every)

    def fragments_due(self, step: int, sync_every: int) -> List[int]:
        return streaming.fragments_due(step, self.fragments, sync_every)

    def fragment_applier(self, trainer) -> Callable:
        fs = streaming.FragmentSync(trainer, donate=False)
        return lambda state, fragment: fs.apply(state, fragment)

    def jitted_fragment(self, trainer, fragment: int):
        fs = getattr(trainer, "_strategy_fragment_sync", None)
        if fs is None or fs.num_fragments != self.fragments:
            fs = streaming.FragmentSync(trainer)  # donated hot path
            trainer._strategy_fragment_sync = fs
        return fs.jitted(fragment)

    def with_num_fragments(self, fragments: int) -> "StreamingSync":
        return dataclasses.replace(self, fragments=fragments)

    def outer_payload_bytes(self, n_params: float) -> float:
        return n_params * BITS_PER_PARAM / 8.0 / self.fragments

    def legacy_flags(self):
        return {"data_parallel": False, "compression": "none",
                "streaming_fragments": self.fragments}

    def validate(self, dcfg) -> None:
        if self.fragments <= 0:
            raise ValueError(f"fragments must be >= 1, got {self.fragments}")
        if self.fragments > dcfg.sync_every:
            raise ValueError(
                f"streaming fragments ({self.fragments}) must be <= "
                f"sync_every ({dcfg.sync_every}): with P > H the fragment "
                "stride degenerates to 1 and fragment syncs collide"
            )


# int4 registers itself through the same public API as any out-of-tree
# strategy would (see its module docstring) — imported last so the registry
# above exists.
from repro.core import sync_int4  # noqa: E402,F401  (registration side effect)
