"""Process-wide executable cache keyed by *static shape signature*.

A sweep builds a fresh ``DiLoCo`` trainer (and ``SuperstepEngine``) per grid
cell, so every cell used to pay a full trace + XLA compile even when the
only difference from the previous cell was a scalar hyperparameter (inner
lr, outer lr, momentum, seed).  With hyperparameters threaded through the
state's ``hparams`` leaf (traced arrays, not Python constants — see
``repro.core.diloco``), two trainers that agree on everything *structural*
produce byte-identical jaxprs — so their executables can be shared.

This module is that sharing point: a dict from hashable signature ->
``jax.jit`` object, plus build counters the benchmarks use to prove "each
distinct cell shape compiles exactly once".  The signature must include the
ambient sharding context (rules + mesh): the traced computation reads
``sharding.current_rules()`` at trace time, so trainers under different
meshes must NOT share.

``sharing(False)`` disables the cache (every lookup builds fresh) — used by
``benchmarks/bench_sweep.py`` to time the historical no-sharing path.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable, Dict, Hashable, Optional

_SHARING: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "jitcache_sharing", default=True
)

_CACHE: Dict[Hashable, Any] = {}
_BUILDS: Dict[Hashable, int] = {}


@contextlib.contextmanager
def sharing(enabled: bool):
    """Context manager: enable/disable cross-instance executable sharing."""
    token = _SHARING.set(enabled)
    try:
        yield
    finally:
        _SHARING.reset(token)


def sharing_enabled() -> bool:
    return _SHARING.get()


def get_or_build(key: Hashable, build: Callable[[], Any],
                 local: Optional[Dict[Hashable, Any]] = None):
    """Return the cached executable for ``key``, building (and counting the
    build) on miss.

    With sharing enabled the process-wide cache is used; with sharing
    disabled the caller's ``local`` per-instance cache is used instead —
    the historical one-cache-per-trainer/engine behavior, NOT
    build-on-every-call (a no-sharing benchmark baseline must still cache
    within an instance, as the pre-sharing code did).  Builds are counted
    either way.
    """
    cache = _CACHE if _SHARING.get() else local
    if cache is None:
        _BUILDS[key] = _BUILDS.get(key, 0) + 1
        return build()
    fn = cache.get(key)
    if fn is None:
        fn = build()
        cache[key] = fn
        _BUILDS[key] = _BUILDS.get(key, 0) + 1
    return fn


def build_count() -> int:
    """Total executable builds since the last ``reset_stats()``."""
    return sum(_BUILDS.values())


def builds_by_kind() -> Dict[str, int]:
    """Build counts grouped by the key's leading tag (``"diloco"``,
    ``"superstep"``, ``"cellbatch"``) — the benchmark's reuse assertion."""
    out: Dict[str, int] = {}
    for key, n in _BUILDS.items():
        kind = key[0] if isinstance(key, tuple) and key else str(key)
        out[kind] = out.get(kind, 0) + n
    return out


def distinct_keys() -> int:
    return len(_BUILDS)


def reset_stats() -> None:
    _BUILDS.clear()


def clear() -> None:
    """Drop every cached executable (tests / memory pressure)."""
    _CACHE.clear()
    _BUILDS.clear()


def context_key() -> tuple:
    """The ambient-sharding part of every signature: trainers under
    different rules/mesh trace different constraint ops and must not share."""
    from repro import sharding

    rules = sharding.current_rules()
    return (frozenset(rules.items()) if rules else None, sharding.current_mesh())
