"""Streaming DiLoCo (Douillard et al. 2025): fragment-wise outer sync.

Parameters are partitioned into P fragments; fragment p syncs every H steps
at offset p*(H/P), so *some* fragment syncs every H/P steps.  Total bytes
are unchanged (paper Appendix A notes this) but peak per-step communication
drops by P and the sync can overlap inner compute.  Fragments keep their own
slice of the outer momentum; the global model is updated fragment-wise.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import outer_opt


def fragment_assignment(params, num_fragments: int) -> List[int]:
    """Leaf index -> fragment id (round-robin over flattened leaves)."""
    n = len(jax.tree.leaves(params))
    return [i % num_fragments for i in range(n)]


def fragments_due(step: int, num_fragments: int, sync_every: int) -> List[int]:
    """Which fragments sync at `step` (1-based step count, like step%H==0)."""
    if num_fragments <= 0:
        return []
    stride = max(sync_every // num_fragments, 1)
    due = []
    for p in range(num_fragments):
        if (step - p * stride) % sync_every == 0:
            due.append(p)
    return due


def outer_sync_fragment(trainer, state: dict, fragment: int) -> dict:
    """Outer sync restricted to one fragment's leaves."""
    dcfg = trainer.dcfg
    assert not dcfg.data_parallel
    assign = fragment_assignment(state["global_params"], dcfg.streaming_fragments)

    gleaves, treedef = jax.tree.flatten(state["global_params"])
    ileaves = jax.tree.leaves(state["inner_params"])
    mleaves = jax.tree.leaves(state["outer_m"])

    new_g, new_i, new_m = [], [], []
    for idx, (g, p, m) in enumerate(zip(gleaves, ileaves, mleaves)):
        if assign[idx] != fragment:
            new_g.append(g)
            new_i.append(p)
            new_m.append(m)
            continue
        delta = jnp.mean(g[None].astype(jnp.float32) - p.astype(jnp.float32), axis=0)
        (g2,), (m2,) = outer_opt.outer_step(
            (g,), (delta,), (m,),
            lr=dcfg.outer_lr, mu=dcfg.outer_momentum, nesterov=dcfg.nesterov,
        )
        new_g.append(g2)
        new_m.append(m2)
        new_i.append(jnp.broadcast_to(g2[None].astype(p.dtype), p.shape))

    return {
        **state,
        "global_params": jax.tree.unflatten(treedef, new_g),
        "inner_params": jax.tree.unflatten(treedef, new_i),
        "outer_m": jax.tree.unflatten(treedef, new_m),
    }


def streaming_train_step(trainer, state: dict, batch: dict):
    """Python-scheduled streaming step (inner step + any due fragments)."""
    state, metrics = trainer.inner_step(state, batch)
    step = int(state["step"])
    for frag in fragments_due(step, trainer.dcfg.streaming_fragments, trainer.dcfg.sync_every):
        state = outer_sync_fragment(trainer, state, frag)
    return state, metrics
