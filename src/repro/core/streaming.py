"""Streaming DiLoCo (Douillard et al. 2025): fragment-wise outer sync.

Parameters are partitioned into P fragments; fragment p syncs every H steps
at offset p*(H/P), so *some* fragment syncs every H/P steps.  Total bytes
are unchanged (paper Appendix A notes this) but peak per-step communication
drops by P and the sync can overlap inner compute.  Fragments keep their own
slice of the outer momentum; the global model is updated fragment-wise.

Hot-path design: the leaf->fragment partition is STATIC — computed once from
the abstract parameter tree — and each fragment's sync is a cached jitted
executable (``FragmentSync.jitted``) with donated state buffers, so the
per-step loop pays no Python tree-flatten and no retrace after the first
call.  The un-jitted ``FragmentSync.apply`` is traceable: the compiled
superstep engine (``repro.core.superstep``) embeds it behind ``lax.cond`` so
a whole outer round — inner steps plus mid-round fragment syncs — is ONE
executable.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import outer_opt


def fragment_assignment(params, num_fragments: int) -> List[int]:
    """Leaf index -> fragment id (round-robin over flattened leaves)."""
    n = len(jax.tree.leaves(params))
    return [i % num_fragments for i in range(n)]


def fragment_stride(num_fragments: int, sync_every: int) -> int:
    return max(sync_every // num_fragments, 1)


def is_due(step, fragment: int, num_fragments: int, sync_every: int):
    """Whether ``fragment`` syncs at (1-based) ``step``.

    ``step`` may be a traced int32 scalar — this is the predicate the
    compiled superstep evaluates on-device inside its scan body.
    """
    stride = fragment_stride(num_fragments, sync_every)
    return (step - fragment * stride) % sync_every == 0


def fragments_due(step: int, num_fragments: int, sync_every: int) -> List[int]:
    """Which fragments sync at `step` (1-based step count, like step%H==0)."""
    if num_fragments <= 0:
        return []
    return [
        p for p in range(num_fragments)
        if bool(is_due(step, p, num_fragments, sync_every))
    ]


class FragmentSync:
    """Fragment-wise outer sync with a precomputed static partition.

    One instance per trainer; ``jitted(p)`` returns a cached, compiled
    executable for fragment ``p`` (state buffers donated when ``donate``),
    and ``apply`` is the traceable body shared with the superstep engine.
    """

    def __init__(self, trainer, *, donate: bool = True):
        strat = trainer.sync
        assert strat.uses_outer_opt
        assert strat.num_fragments > 0
        self.trainer = trainer
        self.num_fragments = strat.num_fragments
        self.assignment = fragment_assignment(
            trainer.model.abstract_params(jnp.float32), self.num_fragments
        )
        self._donate = donate
        self._jitted: Dict[int, object] = {}

    def apply(self, state: dict, fragment: int) -> dict:
        """Outer sync restricted to one fragment's leaves (traceable; the
        Python flatten below runs once per trace, never per call)."""
        dcfg = self.trainer.dcfg
        hp = state["hparams"]
        gleaves, treedef = jax.tree.flatten(state["global_params"])
        ileaves = jax.tree.leaves(state["inner_params"])
        mleaves = jax.tree.leaves(state["outer_m"])

        new_g, new_i, new_m = [], [], []
        for idx, (g, p, m) in enumerate(zip(gleaves, ileaves, mleaves)):
            if self.assignment[idx] != fragment:
                new_g.append(g)
                new_i.append(p)
                new_m.append(m)
                continue
            # replica mean folded into the reduction — no (M, ...) fp32 stack
            delta = g.astype(jnp.float32) - jnp.mean(p, axis=0, dtype=jnp.float32)
            (g2,), (m2,) = outer_opt.outer_step(
                (g,), (delta,), (m,),
                lr=hp["outer_lr"], mu=hp["outer_momentum"],
                nesterov=dcfg.nesterov,
            )
            new_g.append(g2)
            new_m.append(m2)
            new_i.append(jnp.broadcast_to(g2[None].astype(p.dtype), p.shape))

        return {
            **state,
            "global_params": jax.tree.unflatten(treedef, new_g),
            "inner_params": jax.tree.unflatten(treedef, new_i),
            "outer_m": jax.tree.unflatten(treedef, new_m),
        }

    def jitted(self, fragment: int):
        fn = self._jitted.get(fragment)
        if fn is None:
            fn = jax.jit(
                partial(self.apply, fragment=fragment),
                donate_argnums=(0,) if self._donate else (),
            )
            self._jitted[fragment] = fn
        return fn


def _cached_sync(trainer) -> FragmentSync:
    sync = getattr(trainer, "_fragment_sync", None)
    if sync is None or sync.num_fragments != trainer.sync.num_fragments:
        # no donation in the convenience path: callers may hold other
        # references to the state they pass in
        sync = FragmentSync(trainer, donate=False)
        trainer._fragment_sync = sync
    return sync


def outer_sync_fragment(trainer, state: dict, fragment: int) -> dict:
    """Outer sync restricted to one fragment's leaves (cached compiled)."""
    return _cached_sync(trainer).jitted(fragment)(state)


def streaming_train_step(trainer, state: dict, batch: dict):
    """Python-scheduled streaming step (inner step + any due fragments)."""
    state, metrics = trainer.inner_step(state, batch)
    step = int(state["step"])
    for frag in fragments_due(step, trainer.sync.num_fragments, trainer.dcfg.sync_every):
        state = outer_sync_fragment(trainer, state, frag)
    return state, metrics
