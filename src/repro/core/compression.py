"""Outer-gradient (Δ) compression for the cross-pod all-reduce.

Beyond-paper optimization: DiLoCo's outer gradients are parameter-space
deltas accumulated over H inner steps — empirically low dynamic range, so
int8 symmetric quantization with error feedback costs ~nothing in quality
while cutting cross-datacenter bytes another 2x vs bf16 (8x vs fp32).
The Pallas kernel version (per-128-block scales) is in
``repro.kernels.delta_quant``; this module is the jnp reference used on CPU
and by the trainer by default.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def int8_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32 scalar)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _int8_roundtrip(v):
    q, s = int8_quantize(v)
    return int8_dequantize(q, s)


def compress_tree(delta, ef=None, *, quantize=None):
    """Quantize+dequantize every leaf, tracking error feedback.

    Returns (transmitted_delta, new_error_feedback).  The transmitted value
    is what the all-reduce actually carries (quantized payload semantics);
    the residual is re-injected next round so the bias does not accumulate.
    ``quantize`` (fp32 leaf -> dequantized fp32 leaf) defaults to the
    per-tensor int8 path; sync strategies (``repro.core.sync``) pass their
    own codec (e.g. int4 block quantization) through the same EF machinery.
    """
    qfn = _int8_roundtrip if quantize is None else quantize

    def one(d, e):
        v = d.astype(jnp.float32) + (e if e is not None else 0.0)
        deq = qfn(v)
        return deq.astype(d.dtype), (v - deq)

    flat_d, treedef = jax.tree.flatten(delta)
    flat_e = jax.tree.leaves(ef) if ef is not None else [None] * len(flat_d)
    pairs = [one(d, e) for d, e in zip(flat_d, flat_e)]
    sent = jax.tree.unflatten(treedef, [p for p, _ in pairs])
    new_ef = jax.tree.unflatten(treedef, [e for _, e in pairs])
    return sent, new_ef


def init_error_feedback(params, num_replicas: int):
    return jax.tree.map(
        lambda p: jnp.zeros((num_replicas, *p.shape), jnp.float32), params
    )


def abstract_error_feedback(params, num_replicas: int):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct((num_replicas, *p.shape), jnp.float32), params
    )
