"""Bounded exponential backoff for transient I/O failures.

The fault-tolerant runtime wraps every side-effecting I/O boundary
(checkpoint save/restore, ledger appends) in :func:`call`.  The policy is
deliberately tiny: a fixed number of attempts with exponentially growing,
capped delays.  Determinism matters more than sophistication here — tests
pass a fake ``sleep`` to assert the exact delay sequence, and chaos runs
must replay identically from a :class:`repro.core.faults.FaultSchedule`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional, Tuple, Type


@dataclasses.dataclass(frozen=True)
class Policy:
    """Backoff policy: ``attempts`` total tries, delays ``base_delay *
    multiplier**k`` (capped at ``max_delay``) between consecutive tries."""

    attempts: int = 3
    base_delay: float = 0.05
    multiplier: float = 2.0
    max_delay: float = 2.0

    def __post_init__(self):
        if self.attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {self.attempts}")


DEFAULT = Policy()


def delays(policy: Policy = DEFAULT) -> Iterator[float]:
    """The ``attempts - 1`` sleep durations between consecutive tries."""
    d = policy.base_delay
    for _ in range(policy.attempts - 1):
        yield min(d, policy.max_delay)
        d *= policy.multiplier


def call(
    fn: Callable,
    *,
    policy: Policy = DEFAULT,
    retry_on: Tuple[Type[BaseException], ...] = (OSError,),
    sleep: Callable[[float], None] = time.sleep,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
):
    """Invoke ``fn()`` with bounded retries.

    Exceptions not in ``retry_on`` propagate immediately; the final
    attempt's exception propagates unwrapped.  ``on_retry(attempt, exc)``
    fires before each sleep (attempt is 1-based), and ``sleep`` is
    injectable so tests run on a deterministic clock.
    """
    pause = iter(delays(policy))
    for attempt in range(1, policy.attempts + 1):
        try:
            return fn()
        except retry_on as exc:
            if attempt == policy.attempts:
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            sleep(next(pause))
