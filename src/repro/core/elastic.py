"""Elastic scaling + straggler mitigation for DiLoCo.

DiLoCo's outer boundary is a natural fault-isolation point:

* **Straggler / failure dropout** — ``participation_weights(mask)`` feeds
  ``DiLoCo.outer_sync(state, weights=...)``: replicas that miss the sync
  deadline are excluded from the Δ-average (weighted partial participation,
  FedOpt semantics).  A dead replica only loses its inner progress since the
  last sync.
* **Elastic resize** — ``resize_replicas``: M can change *between rounds*.
  Surviving replicas keep their inner optimizer state; new replicas
  bootstrap from the global model with a genuinely cold-start inner
  optimizer: zero AdamW moments AND a zero Adam ``count``, so their first
  update gets the correct ``1-β^1`` bias correction instead of inheriting
  replica 0's step count against zeroed moments (which under-scales the
  debiased moments by ``(1-β^1)/(1-β^count)``).  int8 error-feedback slices
  are grown with zero residuals / shrunk consistently.  Outer momentum is
  global-shaped, so it carries over exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def participation_weights(mask) -> jax.Array:
    """(M,) bool -> normalized weights; all-False falls back to uniform."""
    m = jnp.asarray(mask, jnp.float32)
    total = m.sum()
    return jnp.where(total > 0, m, jnp.ones_like(m))


def resize_replicas(trainer, state: dict, new_m: int) -> dict:
    """Return a state with ``new_m`` replicas (DiLoCo only, between rounds).

    The old replica count is derived from the state itself (not
    ``trainer.M``), so this also serves elastic *restore*: a trainer already
    configured for M' can resize a checkpointed M-replica state.
    """
    assert trainer.sync.uses_outer_opt, "elastic resize needs a global model"
    gparams = state["global_params"]
    old_m = int(jax.tree.leaves(state["inner_params"])[0].shape[0])

    def grow(leaf, fresh):
        leaf = jnp.asarray(leaf)
        if new_m <= old_m:
            return leaf[:new_m]
        extra = jnp.repeat(jnp.asarray(fresh)[None], new_m - old_m, 0).astype(leaf.dtype)
        return jnp.concatenate([leaf, extra], axis=0)

    new_inner = jax.tree.map(grow, state["inner_params"], gparams)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), gparams)
    count = jnp.asarray(state["inner_opt"]["count"])
    # fresh replicas start at count=0: cold-start AdamW bias correction
    new_count = grow(count, jnp.zeros((), count.dtype))
    new_opt = {
        "m": jax.tree.map(grow, state["inner_opt"]["m"], zeros),
        "v": jax.tree.map(grow, state["inner_opt"]["v"], zeros),
        "count": new_count,
    }
    out = {**state, "inner_params": new_inner, "inner_opt": new_opt}
    for key in trainer.sync.extra_state_keys:
        # strategy-owned per-replica leaves (e.g. quantizer error feedback)
        # resize like the inner state: fresh replicas have transmitted
        # nothing, so their slices are zero
        if key in state:
            out[key] = jax.tree.map(grow, state[key], zeros)
    return out
