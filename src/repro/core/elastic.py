"""Elastic scaling + straggler mitigation for DiLoCo.

DiLoCo's outer boundary is a natural fault-isolation point:

* **Straggler / failure dropout** — ``participation_weights(mask)`` feeds
  ``DiLoCo.outer_sync(state, weights=...)``: replicas that miss the sync
  deadline are excluded from the Δ-average (weighted partial participation,
  FedOpt semantics).  A dead replica only loses its inner progress since the
  last sync.
* **Elastic resize** — ``resize_replicas``: M can change *between rounds*.
  Surviving replicas keep their inner optimizer state; new replicas
  bootstrap from the global model with fresh inner state.  Outer momentum is
  global-shaped, so it carries over exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def participation_weights(mask) -> jax.Array:
    """(M,) bool -> normalized weights; all-False falls back to uniform."""
    m = jnp.asarray(mask, jnp.float32)
    total = m.sum()
    return jnp.where(total > 0, m, jnp.ones_like(m))


def resize_replicas(trainer, state: dict, new_m: int) -> dict:
    """Return a state with ``new_m`` replicas (DiLoCo only, between rounds)."""
    assert not trainer.dcfg.data_parallel
    old_m = trainer.M
    gparams = state["global_params"]

    def grow(leaf, fresh):
        if new_m <= old_m:
            return leaf[:new_m]
        extra = jnp.repeat(fresh[None], new_m - old_m, 0).astype(leaf.dtype)
        return jnp.concatenate([leaf, extra], axis=0)

    new_inner = jax.tree.map(grow, state["inner_params"], gparams)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), gparams)
    new_opt = {
        "m": jax.tree.map(grow, state["inner_opt"]["m"], zeros),
        "v": jax.tree.map(grow, state["inner_opt"]["v"], zeros),
        "count": grow(state["inner_opt"]["count"], state["inner_opt"]["count"][0]),
    }
    out = {**state, "inner_params": new_inner, "inner_opt": new_opt}
    if "ef" in state:
        out["ef"] = jax.tree.map(grow, state["ef"], zeros)
    return out
