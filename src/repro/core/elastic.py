"""Elastic scaling + straggler mitigation for DiLoCo.

DiLoCo's outer boundary is a natural fault-isolation point:

* **Straggler / failure dropout** — ``participation_weights(mask)`` feeds
  ``DiLoCo.outer_sync(state, weights=...)``: replicas that miss the sync
  deadline are excluded from the Δ-average (weighted partial participation,
  FedOpt semantics).  A dead replica only loses its inner progress since the
  last sync.
* **Elastic resize** — ``resize_replicas``: M can change *between rounds*.
  Surviving replicas keep their inner optimizer state; new replicas
  bootstrap from the global model with a genuinely cold-start inner
  optimizer: zero AdamW moments AND a zero Adam ``count``, so their first
  update gets the correct ``1-β^1`` bias correction instead of inheriting
  replica 0's step count against zeroed moments (which under-scales the
  debiased moments by ``(1-β^1)/(1-β^count)``).  int8 error-feedback slices
  are grown with zero residuals / shrunk consistently.  Outer momentum is
  global-shaped, so it carries over exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def participation_weights(mask) -> jax.Array:
    """(M,) bool -> float32 weights summing to 1 over the survivors.

    Raises ``ValueError`` on an all-dead mask: silently falling back to a
    uniform average would sync from replicas that did no work this round,
    and dividing by a zero survivor count would NaN the global model.
    Host-side entry point — the mask must be concrete (the train loop
    builds it from the fault schedule before handing the *weights* to the
    compiled round as a traced operand).
    """
    m = jnp.asarray(mask, jnp.float32)
    total = m.sum()
    if not bool(total > 0):
        raise ValueError(
            "all-dead participation mask: every replica is excluded from "
            "the outer sync — the round cannot produce a global update"
        )
    return m / total


def reseed_replicas(trainer, state: dict, rejoin_mask) -> dict:
    """Re-seed the masked replicas from the global model (between rounds).

    A replica that rejoins after missing rounds holds stale inner params
    and — worse — stale AdamW moments and a stale Adam ``count``.  This
    applies ``resize_replicas``'s cold-start semantics *in place*: where
    ``rejoin_mask`` is True, inner params are reset to the global params,
    AdamW moments and error-feedback residuals to zero, and the Adam
    ``count`` to zero (correct ``1-β^1`` bias correction on the first
    post-rejoin step).  Surviving replicas are untouched bitwise.

    The mask is a **traced** (M,) operand — one compiled executable (cached
    by the trainer's static signature, PR-4 pattern) serves every mask
    sequence with zero recompiles.  Call at a round *start*, after the
    previous round's outer sync.
    """
    assert trainer.sync.uses_outer_opt, "reseed needs a global model"
    from repro.core import jitcache
    from repro.core.diloco import static_signature

    extra = tuple(k for k in trainer.sync.extra_state_keys if k in state)
    key = ("reseed", static_signature(trainer), extra)

    def build():
        def fn(st, mask):
            def sel(leaf, fresh):
                m = mask.reshape((mask.shape[0],) + (1,) * (leaf.ndim - 1))
                return jnp.where(m, fresh.astype(leaf.dtype), leaf)

            gp = st["global_params"]
            out = dict(st)
            out["inner_params"] = jax.tree.map(
                lambda p, g: sel(p, jnp.broadcast_to(g[None], p.shape)),
                st["inner_params"], gp,
            )
            opt = st["inner_opt"]
            zero = lambda leaf: sel(leaf, jnp.zeros_like(leaf))
            out["inner_opt"] = {
                "m": jax.tree.map(zero, opt["m"]),
                "v": jax.tree.map(zero, opt["v"]),
                "count": jnp.where(mask, jnp.zeros_like(opt["count"]), opt["count"]),
            }
            for k in extra:
                out[k] = jax.tree.map(zero, st[k])
            return out

        return jax.jit(fn, donate_argnums=(0,))

    fn = jitcache.get_or_build(key, build, trainer._jit_cache)
    return fn(state, jnp.asarray(rejoin_mask, bool))


def resize_replicas(trainer, state: dict, new_m: int) -> dict:
    """Return a state with ``new_m`` replicas (DiLoCo only, between rounds).

    The old replica count is derived from the state itself (not
    ``trainer.M``), so this also serves elastic *restore*: a trainer already
    configured for M' can resize a checkpointed M-replica state.
    """
    assert trainer.sync.uses_outer_opt, "elastic resize needs a global model"
    gparams = state["global_params"]
    old_m = int(jax.tree.leaves(state["inner_params"])[0].shape[0])

    def grow(leaf, fresh):
        leaf = jnp.asarray(leaf)
        if new_m <= old_m:
            return leaf[:new_m]
        extra = jnp.repeat(jnp.asarray(fresh)[None], new_m - old_m, 0).astype(leaf.dtype)
        return jnp.concatenate([leaf, extra], axis=0)

    new_inner = jax.tree.map(grow, state["inner_params"], gparams)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), gparams)
    count = jnp.asarray(state["inner_opt"]["count"])
    # fresh replicas start at count=0: cold-start AdamW bias correction
    new_count = grow(count, jnp.zeros((), count.dtype))
    new_opt = {
        "m": jax.tree.map(grow, state["inner_opt"]["m"], zeros),
        "v": jax.tree.map(grow, state["inner_opt"]["v"], zeros),
        "count": new_count,
    }
    out = {**state, "inner_params": new_inner, "inner_opt": new_opt}
    for key in trainer.sync.extra_state_keys:
        # strategy-owned per-replica leaves (e.g. quantizer error feedback)
        # resize like the inner state: fresh replicas have transmitted
        # nothing, so their slices are zero
        if key in state:
            out[key] = jax.tree.map(grow, state[key], zeros)
    return out
