"""Idealized wall-clock time model (paper Appendix A).

Computation: C = 6·N·D flops over R chips of Q flops/s each.
Communication: bandwidth-optimal all-reduce of N params over R nodes in a
(W, ε) network takes 2·N_bits/W·(1−1/R) + ε  [Patarasuk & Yuan 2009].

Data-Parallel:   all-reduce over the CROSS-datacenter network every step.
DiLoCo M=1:      same per-step all-reduce; the outer step is LOCAL (a
                 single replica group has nobody to exchange deltas with —
                 the per-step all-reduce already keeps every chip in sync),
                 so no extra communication is billed.
DiLoCo M≥2:      per-step all-reduce stays INSIDE a datacenter (R/M nodes,
                 high-bandwidth net); the outer all-reduce crosses every H
                 steps ACROSS THE M REPLICA GROUPS (Appendix A: each group
                 pre-reduces internally, so the cross-datacenter collective
                 has M participants, not R).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Network:
    name: str
    bandwidth: float   # bits / s
    latency: float     # s


HIGH = Network("high", 400e9, 1e-4)
MEDIUM = Network("medium", 100e9, 1e-3)
LOW = Network("low", 10e9, 1e-2)

CHIP_FLOPS = 300e12        # Appendix A: between v5e (197) and v6e (918) @50% MFU
BITS_PER_PARAM = 16        # bf16 weights/grads (paper §3)
TOKENS_PER_CHIP = 8192     # idealized chips R ∝ global batch (Appendix A.3)


def num_chips(batch_tokens: int) -> int:
    return max(1, batch_tokens // TOKENS_PER_CHIP)


def allreduce_time(n_params: float, r_nodes: int, net: Network, bits=BITS_PER_PARAM) -> float:
    if r_nodes <= 1:
        return 0.0
    return 2.0 * n_params * bits / net.bandwidth * (1.0 - 1.0 / r_nodes) + net.latency


def allreduce_bytes_time(payload_bytes: float, r_nodes: int, net: Network) -> float:
    """``allreduce_time`` for an arbitrary per-participant payload — the
    entry point for sync strategies whose outer payload is not
    ``N * BITS_PER_PARAM`` (int8/int4 quantization, per-fragment slices)."""
    if r_nodes <= 1:
        return 0.0
    return 2.0 * payload_bytes * 8.0 / net.bandwidth * (1.0 - 1.0 / r_nodes) + net.latency


def compute_time(n_params: float, tokens: float, r_chips: int, q=CHIP_FLOPS) -> float:
    return 6.0 * n_params * tokens / (r_chips * q)


def train_time(
    n_params: float,
    token_budget: float,
    batch_tokens: int,
    *,
    algorithm: str,          # "dp" | "diloco"
    m_replicas: int = 1,
    sync_every: int = 30,
    cross_net: Network = MEDIUM,
    within_net: Network = HIGH,
    outer_payload_bytes: float = None,
    outer_syncs_per_round: int = 1,
    straggler_factor: float = 1.0,
) -> dict:
    """End-to-end idealized wall-clock seconds (Appendix A.3).

    ``outer_payload_bytes`` / ``outer_syncs_per_round`` route the sync
    strategy's comm accounting (``SyncStrategy.outer_payload_bytes`` /
    ``.sync_events_per_round``) into the cross-datacenter term: int8 halves
    the per-event payload, int4 quarters it, streaming sends 1/P of the
    payload P times per round (same total bytes, Appendix A — but P latency
    hits).  Defaults reproduce the paper's full-precision bf16 accounting.
    The per-step gradient all-reduce (DP and the DiLoCo inner term) always
    bills full-precision grads — outer-Δ compression does not touch it.

    ``straggler_factor`` (>= 1) scales the compute term for heterogeneous
    replicas: each outer round runs at the pace of its slowest
    *participating* replica, so under a fault schedule the factor is
    ``FaultSchedule.mean_slowdown(rounds, M)`` — the mean over rounds of
    the max slowdown among survivors.  The default (1.0) is bitwise
    identical to the fault-free model.
    """
    steps = token_budget / batch_tokens
    r = num_chips(batch_tokens)
    comp = compute_time(n_params, token_budget, r)
    straggler_s = 0.0
    if straggler_factor != 1.0:
        if straggler_factor < 1.0:
            raise ValueError(f"straggler_factor must be >= 1, got {straggler_factor}")
        straggler_s = comp * (straggler_factor - 1.0)
        comp = comp + straggler_s
    if outer_payload_bytes is None:
        outer_payload_bytes = n_params * BITS_PER_PARAM / 8.0

    if algorithm == "dp":
        comm = allreduce_time(n_params, r, cross_net) * steps
    elif m_replicas == 1:
        # single replica group: the per-step all-reduce spans the same R
        # chips as DP (over the cross net), and the outer all-reduce over
        # M=1 groups is a no-op — allreduce_time(·, 1, ·) == 0 below, so
        # this branch is the m>=2 formula with within_net := cross_net
        comm = allreduce_time(n_params, r, cross_net) * steps
    else:
        # Appendix A: inner syncs stay within each group's datacenter; the
        # outer sync is an all-reduce across the M replica groups
        inner = allreduce_time(n_params, max(r // m_replicas, 1), within_net) * steps
        outer = (
            allreduce_bytes_time(outer_payload_bytes, m_replicas, cross_net)
            * outer_syncs_per_round * steps / sync_every
        )
        comm = inner + outer
    out = {
        "steps": steps,
        "chips": r,
        "compute_s": comp,
        "comm_s": comm,
        "total_s": comp + comm,
    }
    if straggler_s:
        out["straggler_s"] = straggler_s
    return out
