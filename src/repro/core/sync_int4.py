"""int4 block-quantized outer sync — a strategy added WITHOUT engine edits.

This module is the extensibility proof for ``repro.core.sync``: a new outer
-sync variant registered purely through the public API — no changes to
``superstep.py``, ``cellbatch.py``, or ``checkpoint/checkpointer.py``.  The
engines pick it up through the strategy protocol (one round-end ``apply``,
error-feedback state under the inherited ``"ef"`` leaf), the checkpoint
manifest records its ``int4`` tag, the CU/wall-clock models read its 4x
payload cut from ``outer_payload_bytes``, and the sweep grids select it as
``mode="int4"``.

Quantization reuses the ``delta_quant`` kernel path's block layout: leaves
are flattened and padded to whole (ROWS, LANES) VMEM tiles exactly like the
int8 Pallas kernel (``_to_lanes``), then symmetrically quantized to the
int4 range (±7) with one fp32 scale per block.  The jnp rollout below is
the reference/CPU path (like ``repro.core.compression`` for int8); the TPU
kernel variant drops in by generalizing ``delta_quant``'s clip bound, since
the block geometry is already identical.

Error feedback matters more at 4 bits than 8 (the per-step quantization
error is ~16x larger in variance), so it defaults on, carried per replica
in the same ``"ef"`` residual leaf the int8 strategy uses.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core import sync

QMAX = 7           # symmetric int4: values in [-7, 7]


def int4_block_quantize(x: jax.Array) -> jax.Array:
    """Quantize-dequantize ``x`` through the delta_quant block layout at 4
    bits: one fp32 scale per (ROWS, LANES) tile, values clipped to ±QMAX.
    Returns the dequantized fp32 array (the all-reduce payload semantics —
    what the receiver decodes)."""
    # deferred: the kernel package imports jax.experimental.pallas at module
    # scope, and this module loads with the registry on every trainer import
    # (same lazy-kernel pattern as repro.optim.adamw / repro.models.layers)
    from repro.kernels.delta_quant.delta_quant import LANES, ROWS
    from repro.kernels.delta_quant.ops import _to_lanes

    x2d, n = _to_lanes(x)  # padded to whole (ROWS, LANES) blocks
    nb = x2d.shape[0] // ROWS
    xb = x2d.reshape(nb, ROWS, LANES).astype(jnp.float32)
    scales = jnp.maximum(jnp.abs(xb).max(axis=(1, 2)), 1e-12) / QMAX
    q = jnp.clip(jnp.round(xb / scales[:, None, None]), -QMAX, QMAX)
    deq = q * scales[:, None, None]
    return deq.reshape(-1)[:n].reshape(x.shape)


@sync.register("int4")
@dataclasses.dataclass(frozen=True)
class Int4BlockSync(sync.QuantizedOuterSync):
    """int4 block-quantized outer deltas with error feedback: 4x fewer
    cross-DC bytes than bf16 (0.5 byte/param; the per-block fp32 scale adds
    4/(ROWS*LANES) ~ 1.2e-4 byte/param, ignored by the accounting)."""

    error_feedback: bool = True
    extra_state_keys: ClassVar[tuple] = ("ef",)

    def quantize_leaf(self, v: jax.Array) -> jax.Array:
        # v is the stacked (M, ...) per-replica delta: quantize each
        # replica's slice independently (vmap over the replica axis), so no
        # block — and no scale — ever spans two replicas' transmissions and
        # a real distributed implementation can compute identical payloads
        # replica-locally
        return jax.vmap(int4_block_quantize)(v)

    def outer_payload_bytes(self, n_params: float) -> float:
        return 0.5 * n_params  # 4 bits/param
