"""The paper's own model family (Table 3): Chinchilla-style decoder-only
transformers with QK-norm, z-loss, vocab 32768, seq 2048, MHA, GeLU MLP.

Also provides the reduced CPU "ladder" used by the scaling-law benchmarks in
this container (same family, smaller widths).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig

# scale -> (layers, heads, d_model(QKV dim), d_ff(hidden))
PAPER_TABLE3 = {
    "35m": (6, 8, 512, 2048),
    "90m": (9, 12, 768, 3072),
    "180m": (12, 16, 1024, 4096),
    "330m": (15, 20, 1280, 5120),
    "550m": (18, 24, 1536, 6144),
    "1.3b": (24, 32, 2048, 8192),
    "2.4b": (30, 40, 2560, 10240),
    "4b": (36, 48, 3072, 12288),
    "10b": (48, 64, 4096, 16384),
}

# paper token budgets (Table 3)
PAPER_TOKEN_BUDGETS = {
    "35m": 700e6, "90m": 1.8e9, "180m": 3.6e9, "330m": 6.6e9,
    "550m": 11e9, "1.3b": 26e9, "2.4b": 48e9, "4b": 80e9, "10b": 200e9,
}


def chinchilla_config(scale: str) -> ModelConfig:
    layers, heads, d_model, d_ff = PAPER_TABLE3[scale]
    return ModelConfig(
        name=f"chinchilla-{scale}",
        family="dense",
        n_layers=layers,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=heads,           # MHA
        head_dim=d_model // heads,
        d_ff=d_ff,
        vocab_size=32_768,          # paper: 32k padded to a power of 2
        act="gelu",
        glu=False,                  # NanoDO-style plain GeLU MLP
        qk_norm=True,               # paper §3 (Wortsman et al.)
        tie_embeddings=True,
        max_seq_len=2048,
        z_loss=1e-4,                # paper §3 (Chowdhery et al.)
    )


def tiny_ladder() -> dict:
    """CPU-runnable miniature of the same family for the loss-vs-N sweeps.

    Widths follow the paper's aspect-ratio recipe; param counts ~0.25M-4M so
    Chinchilla budgets (D=20N) complete on one CPU core.
    """
    grid = {
        "t0": (2, 2, 64, 256),
        "t1": (3, 4, 96, 384),
        "t2": (4, 4, 128, 512),
        "t3": (5, 8, 192, 768),
    }
    out = {}
    for name, (layers, heads, d_model, d_ff) in grid.items():
        out[name] = ModelConfig(
            name=f"tiny-{name}",
            family="dense",
            n_layers=layers,
            d_model=d_model,
            n_heads=heads,
            n_kv_heads=heads,
            head_dim=d_model // heads,
            d_ff=d_ff,
            vocab_size=256,
            act="gelu",
            glu=False,
            qk_norm=True,
            tie_embeddings=True,
            max_seq_len=256,
            remat=False,
        )
    return out
