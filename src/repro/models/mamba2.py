"""Mamba-2 (SSD — state-space duality) mixer block.

Chunked dual form (arXiv:2405.21060): within chunks of length Q the SSM is
computed as masked attention-like matmuls (MXU-friendly); across chunks a
cheap recurrence carries the (heads, head_dim, d_state) state.  A Pallas
kernel for the intra-chunk part lives in ``repro.kernels.ssd_scan``; this
module is the pure-jnp implementation used as reference and CPU/dry-run path.

Decode uses the classic recurrent update with a conv-state + ssm-state cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import layers


def ssm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    g, n = cfg.ssm_n_groups, cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = di + 2 * g * n
    d_in_proj = 2 * di + 2 * g * n + h
    return {
        "in_proj": layers.dense_spec(d, (d, "embed"), (d_in_proj, "ssm_heads")),
        "conv_w": layers.PSpec((conv_dim, cfg.ssm_conv), ("ssm_heads", None), std=cfg.ssm_conv ** -0.5),
        "conv_b": layers.PSpec((conv_dim,), ("ssm_heads",), init="zeros"),
        "A_log": layers.PSpec((h,), ("ssm_heads",), init="ones"),
        "D": layers.PSpec((h,), ("ssm_heads",), init="ones"),
        "dt_bias": layers.PSpec((h,), ("ssm_heads",), init="zeros"),
        "norm": layers.PSpec((di,), ("ssm_heads",), init="ones"),
        "out_proj": layers.dense_spec(di, (di, "ssm_heads"), (d, "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, g, n, h = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xbc: (b, l, c); w: (c, k)."""
    k = w.shape[-1]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    # unrolled k-tap FIR (k=4): cheap + fusion-friendly
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[:, i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_chunked(
    x: jax.Array,      # (b, l, h, p)
    dt: jax.Array,     # (b, l, h)      softplus'd
    A: jax.Array,      # (h,)           negative
    B: jax.Array,      # (b, l, g, n)
    C: jax.Array,      # (b, l, g, n)
    chunk: int,
    init_state: Optional[jax.Array] = None,   # (b, h, p, n)
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (b,l,h,p), final_state (b,h,p,n))."""
    bsz, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    rep = h // g
    cdt = x.dtype

    # scan over chunks: carries the (b,h,p,n) state; per-chunk work is the
    # quadratic "dual form" on the MXU.  Keeps live memory to one chunk.
    xc = jnp.moveaxis(x.reshape(bsz, nc, chunk, h, p), 1, 0)           # (nc,b,q,h,p)
    dtc = jnp.moveaxis(dt.reshape(bsz, nc, chunk, h), 1, 0).astype(jnp.float32)
    Bh = jnp.moveaxis(jnp.repeat(B.reshape(bsz, nc, chunk, g, n), rep, axis=3), 1, 0)
    Ch = jnp.moveaxis(jnp.repeat(C.reshape(bsz, nc, chunk, g, n), rep, axis=3), 1, 0)

    iq = jnp.arange(chunk)
    causal = (iq[:, None] >= iq[None, :])[None, :, :, None]            # (1,i,j,1)
    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    Af = A.astype(jnp.float32)

    @jax.checkpoint
    def step(state, inp):
        xq, dtq, Bq, Cq = inp                     # (b,q,h,p) (b,q,h) (b,q,h,n) x2
        dA = dtq * Af                             # (b,q,h) <= 0
        cum = jnp.cumsum(dA, axis=1)
        total = cum[:, -1, :]                     # (b,h)
        # intra-chunk: L[i,j] = exp(cum_i - cum_j), i >= j
        diff = cum[:, :, None, :] - cum[:, None, :, :]          # (b,i,j,h)
        L = jnp.where(causal, jnp.exp(diff), 0.0)
        cb = jnp.einsum("bihn,bjhn->bijh", Cq.astype(jnp.float32), Bq.astype(jnp.float32))
        w = (cb * L * dtq[:, None, :, :]).astype(cdt)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xq)
        # inter-chunk: contribution of the carried state
        wC = (Cq.astype(jnp.float32) * jnp.exp(cum)[..., None]).astype(cdt)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", wC, state.astype(cdt))
        # state update
        decay_to_end = jnp.exp(total[:, None, :] - cum)          # (b,q,h)
        wB = (Bq.astype(jnp.float32) * (dtq * decay_to_end)[..., None]).astype(cdt)
        S_new = jnp.einsum("bqhn,bqhp->bhpn", wB, xq).astype(jnp.float32)
        state = state * jnp.exp(total)[:, :, None, None] + S_new
        return state, y_intra + y_inter

    if unroll:
        # dry-run mode: unrolled chunks keep trip counts visible to
        # cost_analysis (lax.scan bodies are costed once)
        state, ys = s0, []
        for i in range(nc):
            state, yi = step(state, (xc[i], dtc[i], Bh[i], Ch[i]))
            ys.append(yi)
        final_state, ys = state, jnp.stack(ys)
    else:
        final_state, ys = jax.lax.scan(step, s0, (xc, dtc, Bh, Ch))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l, h, p)
    return y, final_state.astype(cdt)


def ssm_block(
    params: dict,
    xin: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Full Mamba-2 mixer. Train/prefill path (cache=None) or one-step decode.

    cache: {"conv": (b, k-1, conv_dim), "state": (b, h, p, n)}
    """
    di, g, n, h = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    zxbcdt = jnp.einsum("btd,dk->btk", xin, params["in_proj"])
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    new_cache = None
    if cache is None or xin.shape[1] > 1:
        # train / prefill: chunked SSD over the whole sequence
        xbc_raw = xbc
        xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        xs, B, C = jnp.split(xbc, [di, di + g * n], axis=-1)
        xh = xs.reshape(*xs.shape[:2], h, p)
        xh = sharding.shard(xh, "batch", "seq", "ssm_heads", None)
        Bh = B.reshape(*B.shape[:2], g, n)
        Ch = C.reshape(*C.shape[:2], g, n)
        seq = xin.shape[1]
        chunk = min(cfg.ssm_chunk, seq)
        pad = (-seq) % chunk
        if pad:
            # dt padded with 0 => padded steps neither decay nor write state
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            dtp = dt
        y, final_state = ssd_chunked(xh, dtp, A, Bh, Ch, chunk, unroll=cfg.unroll_ssm)
        if pad:
            y = y[:, :seq]
            xh = xh[:, :seq]
        if cache is not None:  # prefill: emit decode cache
            k = cfg.ssm_conv
            new_cache = {
                "conv": xbc_raw[:, -(k - 1):, :].astype(cache["conv"].dtype),
                "state": final_state.astype(cache["state"].dtype),
            }
    else:
        # single-token recurrent decode: xin is (b, 1, d)
        conv_cache = cache["conv"]                          # (b, k-1, conv_dim)
        window = jnp.concatenate([conv_cache, xbc], axis=1)  # (b, k, conv_dim)
        conv_out = jnp.einsum("bkc,ck->bc", window, params["conv_w"]) + params["conv_b"]
        xbc1 = jax.nn.silu(conv_out)[:, None, :]
        xs, B, C = jnp.split(xbc1, [di, di + g * n], axis=-1)
        xh = xs.reshape(xs.shape[0], h, p)                   # (b,h,p)
        Bh = jnp.repeat(B.reshape(B.shape[0], g, n), h // g, axis=1)   # (b,h,n)
        Ch = jnp.repeat(C.reshape(C.shape[0], g, n), h // g, axis=1)
        dt1 = dt[:, 0]                                       # (b,h)
        state = cache["state"].astype(jnp.float32)           # (b,h,p,n)
        dA = jnp.exp(dt1 * A)                                # (b,h)
        upd = dt1[..., None, None] * xh.astype(jnp.float32)[..., None] * Bh.astype(jnp.float32)[:, :, None, :]
        state = state * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))[:, None].astype(xin.dtype)
        new_cache = {"conv": window[:, 1:], "state": state.astype(cache["state"].dtype)}
        xh = xh[:, None]                                     # (b,1,h,p)

    y = y + params["D"].astype(y.dtype)[:, None] * xh.reshape(y.shape[0], -1, h, p)
    y = y.reshape(*y.shape[:2], di)
    y = layers.rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bti,id->btd", y, params["out_proj"])
    return sharding.shard(out, "batch", "seq", "act_embed"), new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int, dtype):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
    }


def ssm_cache_specs(cfg: ModelConfig, batch: int, n_layers: int, dtype):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state
    return {
        "conv": jax.ShapeDtypeStruct((n_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jax.ShapeDtypeStruct((n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
    }


SSM_CACHE_AXES = {
    "conv": ("layers", "batch", None, "ssm_heads"),
    "state": ("layers", "batch", "ssm_heads", None, None),
}
