"""Mixture-of-Experts FFN: token-choice top-k router + capacity dispatch.

Einsum (dispatch/combine) formulation a la Mesh-TF / t5x: tokens are split
into groups, each group dispatches at most ``capacity`` tokens per expert.
Under EP sharding ("experts" -> model axis, "groups" -> data axis) GSPMD
lowers the dispatch einsums to all-to-all-style collectives.  Shared experts
(DeepSeek-MoE) are a fused always-on MLP.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import layers


def moe_specs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    p = {
        "router": layers.PSpec((d, e), ("embed", "experts"), std=d ** -0.5),
        "w_in": layers.PSpec((e, d, f), ("experts", "embed", "expert_ff"), std=d ** -0.5),
        "w_out": layers.PSpec((e, f, d), ("experts", "expert_ff", "embed"), std=f ** -0.5),
    }
    if cfg.glu:
        p["w_gate"] = layers.PSpec((e, d, f), ("experts", "embed", "expert_ff"), std=d ** -0.5)
    if cfg.n_shared_experts:
        p["shared"] = layers.mlp_specs(cfg, d_ff=cfg.n_shared_experts * cfg.moe_d_ff)
    return p


def _capacity(cfg: ModelConfig, group_size: int) -> int:
    cap = int(group_size * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cap, 1)


def moe(params: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """Apply the MoE FFN.  x: (b, t, d).  Returns (y, aux-metrics)."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    s = min(cfg.moe_group_size, b * t)
    tokens = b * t
    assert tokens % s == 0, f"tokens {tokens} not divisible by group size {s}"
    g = tokens // s
    xg = x.reshape(g, s, d)
    xg = sharding.shard(xg, "groups", None, "act_embed")

    # ---- router (fp32 for stability) ------------------------------------
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)             # (g, s, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- capacity-based dispatch ----------------------------------------
    cap = _capacity(cfg, s)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)   # (g, s, k, e)
    # priority: token-major, then expert-choice slot
    flat = onehot.reshape(g, s * k, e)
    pos = jnp.cumsum(flat, axis=1) * flat - flat          # 0-based slot per expert
    keep = (pos < cap).astype(jnp.float32) * flat
    disp = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32) * keep[..., None]
    disp = disp.reshape(g, s, k, e, cap)
    combine = (disp * gate_vals[..., None, None]).sum(axis=2)   # (g, s, e, cap)
    dispatch = disp.sum(axis=2)                                  # (g, s, e, cap)

    cdt = x.dtype
    dispatch = dispatch.astype(cdt)
    combine = combine.astype(cdt)

    # ---- expert computation ----------------------------------------------
    # "expert_cap" sharding (perf iteration, EXPERIMENTS.md Pair B): when the
    # expert count cannot shard over the model axis, sharding the CAPACITY
    # dim keeps expert matmuls local and defers the model-axis all-reduce to
    # the combined (g,s,d) output — e*cap/tokens (~top_k*1.25x) fewer bytes
    # than all-reducing the per-slot partials.
    ein = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    ein = sharding.shard(ein, "groups", "experts", "expert_cap", "act_embed")
    h = jnp.einsum("gecd,edf->gecf", ein, params["w_in"])
    if cfg.glu:
        gt = jnp.einsum("gecd,edf->gecf", ein, params["w_gate"])
        h = layers._act(gt, cfg.act) * h
    else:
        h = layers._act(h, cfg.act)
    h = sharding.shard(h, "groups", "experts", "expert_cap", "expert_ff")
    eout = jnp.einsum("gecf,efd->gecd", h, params["w_out"])
    eout = sharding.shard(eout, "groups", "experts", "expert_cap", "act_embed")
    y = jnp.einsum("gecd,gsec->gsd", eout, combine)
    y = sharding.shard(y, "groups", None, "act_embed")
    y = y.reshape(b, t, d)

    # ---- aux losses --------------------------------------------------------
    # load-balance (Switch): e * sum_e fraction_dispatched_e * mean_prob_e
    frac = dispatch.astype(jnp.float32).sum((1, 3)) / (s * k)    # (g, e)
    mean_prob = probs.mean(axis=1)                               # (g, e)
    aux = (e * (frac * mean_prob).sum(-1)).mean()
    router_z = jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)).mean()
    overflow = 1.0 - keep.sum() / jnp.maximum(flat.sum(), 1.0)

    # ---- shared experts (always-on) ---------------------------------------
    if cfg.n_shared_experts:
        y = y + layers.mlp(params["shared"], x, cfg)

    metrics = {
        "moe_aux": aux * cfg.aux_loss_coef,
        "moe_router_z": router_z * cfg.router_z_coef,
        "moe_overflow": overflow,
    }
    return y, metrics
