"""Common neural-net building blocks (pure functions over param dicts).

Parameters are declared as trees of ``PSpec`` (shape + logical sharding axes
+ initializer); the same declaration drives (a) real initialization for
training, (b) ``ShapeDtypeStruct`` stand-ins for the dry-run, and (c)
``PartitionSpec`` generation.  This is the single source of truth that keeps
the 40-cell dry-run and the smoke tests in lock-step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    std: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def dense_spec(fan_in: int, *shape_axes) -> PSpec:
    """PSpec with 1/sqrt(fan_in) init (NanoDO / Chinchilla convention)."""
    shape = tuple(s for s, _ in shape_axes)
    axes = tuple(a for _, a in shape_axes)
    return PSpec(shape, axes, init="normal", std=float(fan_in) ** -0.5)


def init_params(key: jax.Array, tree, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_pspec)
    keys = jax.random.split(key, max(1, len(leaves)))

    def mk(k, s: PSpec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        return (jax.random.truncated_normal(k, -3.0, 3.0, s.shape, jnp.float32) * s.std).astype(dtype)

    return jax.tree.unflatten(treedef, [mk(k, s) for k, s in zip(keys, leaves)])


def abstract_params(tree, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for dry-run lowering (no device allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree, is_leaf=_is_pspec
    )


def param_partition_specs(tree, extra_leading: Tuple[Optional[str], ...] = ()):
    """PartitionSpec tree under the current sharding rules.

    ``extra_leading`` prepends logical axes (e.g. ("replica",) for the DiLoCo
    replica axis, or ("layers",) inside a scanned stack — callers compose).
    """
    return jax.tree.map(
        lambda s: sharding.spec(*extra_leading, *s.axes), tree, is_leaf=_is_pspec
    )


def stack_specs(tree, n: int):
    """Prepend a stacked-layers axis of size n to every PSpec in the tree."""
    return jax.tree.map(
        lambda s: PSpec((n, *s.shape), ("layers", *s.axes), s.init, s.std),
        tree,
        is_leaf=_is_pspec,
    )


def count_params(tree) -> int:
    return sum(
        int(np.prod(l.shape))
        for l in jax.tree.leaves(tree, is_leaf=_is_pspec)
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> PSpec:
    return PSpec((d,), (None,), init="ones")


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE.  x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA/MQA + optional QK-norm + KV cache)
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig) -> dict:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_spec(d, (d, "embed"), (nh, "heads"), (hd, "head_dim")),
        "wk": dense_spec(d, (d, "embed"), (nkv, "kv_heads"), (hd, "head_dim")),
        "wv": dense_spec(d, (d, "embed"), (nkv, "kv_heads"), (hd, "head_dim")),
        "wo": dense_spec(nh * hd, (nh, "heads"), (hd, "head_dim"), (d, "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_spec(hd)
        p["k_norm"] = rmsnorm_spec(hd)
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int, dtype):
    nkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((n_layers, batch, max_len, nkv, hd), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, nkv, hd), dtype),
    }


def kv_cache_specs(cfg: ModelConfig, batch: int, max_len: int, n_layers: int, dtype):
    nkv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (n_layers, batch, max_len, nkv, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


KV_CACHE_AXES = ("layers", "batch", "kv_seq", "kv_heads", None)


def attention(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,   # cross-attn K/V source
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (k_cache, v_cache)
    cache_index: Optional[jax.Array] = None,
):
    """Multi-head GQA attention.

    Returns (out, (new_k_cache, new_v_cache) or None).
    In decode mode (cache given, x is the new token(s)) keys/values are
    written at ``cache_index`` and attention runs over the whole cache.
    """
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("btd,dnh->btnh", x, params["wq"])
    if kv is None:
        k = jnp.einsum("btd,dnh->btnh", x, params["wk"])
        v = jnp.einsum("btd,dnh->btnh", x, params["wv"])
    else:
        k = jnp.einsum("btd,dnh->btnh", kv[0], params["wk"])
        v = jnp.einsum("btd,dnh->btnh", kv[1], params["wv"])

    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)

    if kv is None:  # self-attention gets RoPE
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    q = sharding.shard(q, "batch", "seq", "heads", None)
    new_cache = None
    if cache is not None:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, cache_index, 0, 0))
        k_cache = sharding.shard(k_cache, "batch", "kv_seq", "kv_heads", None)
        v_cache = sharding.shard(v_cache, "batch", "kv_seq", "kv_heads", None)
        new_cache = (k_cache, v_cache)
        k, v = k_cache, v_cache
        is_causal = True  # valid = causal against absolute positions
    else:
        k = sharding.shard(k, "batch", "kv_seq", "kv_heads", None)
        v = sharding.shard(v, "batch", "kv_seq", "kv_heads", None)
        is_causal = causal and kv is None

    group = nh // nkv
    b, tq = q.shape[0], q.shape[1]
    qg = q.reshape(b, tq, nkv, group, hd)
    out = _attn_core(qg, k, v, positions, is_causal)
    out = out.reshape(b, tq, nh, hd)
    out = sharding.shard(out, "batch", "seq", "heads", None)
    out = jnp.einsum("btnh,nhd->btd", out, params["wo"])
    return sharding.shard(out, "batch", "seq", "act_embed"), new_cache


ATTN_Q_CHUNK = 1024

# On TPU, route attention through the Pallas flash kernel
# (repro.kernels.flash_attention). CPU default: chunked jnp (the oracle).
USE_FLASH_KERNEL = False


def _flash_ok(qg, k, q_positions, is_causal):
    b, tq, nkv, g, hd = qg.shape
    s = k.shape[1]
    return (
        is_causal and tq == s and tq % 128 == 0 and hd in (32, 64, 128, 256)
    )


def _attn_core(qg, k, v, q_positions, is_causal, chunk: int = ATTN_Q_CHUNK):
    """Softmax attention, chunked over query blocks (flash-style schedule).

    qg: (b, tq, nkv, g, hd);  k/v: (b, s, nkv, hd);  q_positions: (b, tq).
    Never materializes more than a (b, nkv, g, chunk, s) logits block — keeps
    32k-token prefill HLO temp memory bounded.  Each block is rematted so the
    backward pass recomputes softmax probabilities instead of storing them
    (flash-attention-backward pattern).  The Pallas kernel
    (repro.kernels.flash_attention) replaces this on TPU.
    Chunks are unrolled (python loop): trip counts stay visible to
    ``cost_analysis`` and XLA can pipeline blocks freely.
    """
    b, tq, nkv, g, hd = qg.shape
    s = k.shape[1]
    cdt = qg.dtype
    scale = hd ** -0.5
    kv_pos = jnp.arange(s)

    if USE_FLASH_KERNEL and _flash_ok(qg, k, q_positions, is_causal):
        from repro.kernels.flash_attention.ops import flash_attention

        qf = qg.transpose(0, 2, 3, 1, 4).reshape(b * nkv * g, tq, hd)
        kf = k.transpose(0, 2, 1, 3).reshape(b * nkv, s, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(b * nkv, s, hd)
        of = flash_attention(qf, kf, vf, True)
        return of.reshape(b, nkv, g, tq, hd).transpose(0, 3, 1, 2, 4)

    @jax.checkpoint
    def block(qb, posb, k, v):
        # qb: (b, tb, nkv, g, hd); posb: (b, tb)
        logits = jnp.einsum("btngh,bsnh->bngts", qb, k).astype(jnp.float32) * scale
        if is_causal:
            mask = kv_pos[None, :] <= posb[..., None]          # (b, tb, s)
            logits = jnp.where(mask[:, None, None, :, :], logits, jnp.finfo(jnp.float32).min)
        probs = jax.nn.softmax(logits, axis=-1).astype(cdt)
        return jnp.einsum("bngts,bsnh->btngh", probs, v)

    if tq <= chunk:
        return block(qg, q_positions, k, v)

    outs = []
    for i in range(0, tq, chunk):
        outs.append(block(qg[:, i : i + chunk], q_positions[:, i : i + chunk], k, v))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain)
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    p = {"w_out": dense_spec(f, (f, "ff"), (d, "embed"))}
    if cfg.glu:
        p["w_in"] = dense_spec(d, (d, "embed"), (f, "ff"))
        p["w_gate"] = dense_spec(d, (d, "embed"), (f, "ff"))
    else:
        p["w_in"] = dense_spec(d, (d, "embed"), (f, "ff"))
    return p


def _act(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(act)


def mlp(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = jnp.einsum("btd,df->btf", x, params["w_in"])
    if cfg.glu:
        g = jnp.einsum("btd,df->btf", x, params["w_gate"])
        h = _act(g, cfg.act) * h
    else:
        h = _act(h, cfg.act)
    h = sharding.shard(h, "batch", "seq", "ff")
    out = jnp.einsum("btf,fd->btd", h, params["w_out"])
    return sharding.shard(out, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# Embedding / unembedding / losses
# ---------------------------------------------------------------------------


def embedding_spec(cfg: ModelConfig) -> PSpec:
    # std 1/sqrt(d): with the sqrt(d) input scaling this gives unit-scale
    # activations AND unit-scale tied logits at init.
    return PSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), std=cfg.d_model ** -0.5)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    return sharding.shard(out, "batch", "seq", "act_embed")


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    logits = jnp.einsum("btd,vd->btv", x, table)
    return sharding.shard(logits, "batch", "seq", "vocab")


def xent_loss(
    logits: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array] = None,
    z_coef: float = 1e-4,
):
    """Cross-entropy with z-loss regularization (paper §3, PaLM-style)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    correct = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - correct
    z = z_coef * jnp.square(lse)
    per_tok = nll + z
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (per_tok * mask).sum() / denom, (nll * mask).sum() / denom
