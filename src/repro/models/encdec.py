"""Encoder–decoder backbone (seamless-m4t-medium assignment).

The audio frontend is a stub per the assignment: ``input_specs()`` provides
precomputed frame embeddings (b, n_frames, d_model).  Encoder is a
bidirectional transformer; decoder adds causal self-attention (KV-cached for
decode) and cross-attention over the encoder output.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import layers


def _enc_block_specs(cfg: ModelConfig) -> dict:
    return {
        "norm1": layers.rmsnorm_spec(cfg.d_model),
        "attn": layers.attention_specs(cfg),
        "norm2": layers.rmsnorm_spec(cfg.d_model),
        "mlp": layers.mlp_specs(cfg),
    }


def _dec_block_specs(cfg: ModelConfig) -> dict:
    return {
        "norm1": layers.rmsnorm_spec(cfg.d_model),
        "self_attn": layers.attention_specs(cfg),
        "normx": layers.rmsnorm_spec(cfg.d_model),
        "cross_attn": layers.attention_specs(cfg),
        "norm2": layers.rmsnorm_spec(cfg.d_model),
        "mlp": layers.mlp_specs(cfg),
    }


def encdec_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": layers.embedding_spec(cfg),
        "enc_stack": layers.stack_specs(_enc_block_specs(cfg), cfg.encoder_layers),
        "enc_norm": layers.rmsnorm_spec(cfg.d_model),
        "dec_stack": layers.stack_specs(_dec_block_specs(cfg), cfg.n_layers),
        "final_norm": layers.rmsnorm_spec(cfg.d_model),
    }


def encode(params: dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (b, t_frames, d) precomputed frontend embeddings."""
    x = frames * jnp.asarray(cfg.d_model ** 0.5, frames.dtype)
    x = sharding.shard(x, "batch", "frames", "act_embed")
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))

    def body(x, gp):
        h = layers.rmsnorm(x, gp["norm1"], cfg.norm_eps)
        out, _ = layers.attention(gp["attn"], h, cfg, positions=positions, causal=False)
        x = x + out
        h = layers.rmsnorm(x, gp["norm2"], cfg.norm_eps)
        x = x + layers.mlp(gp["mlp"], h, cfg)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_stack"])
    else:
        for i in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc_stack"]))
    return layers.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def decode(
    params: dict,
    tokens: jax.Array,
    enc_out: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,   # {"k": (L,b,s,kv,h), "v": ...}
    cache_index=0,
    positions: Optional[jax.Array] = None,
    mode: str = "train",
):
    x = layers.embed(tokens, params["embed"]) * jnp.asarray(cfg.d_model ** 0.5)
    x = x.astype(enc_out.dtype)
    b, t = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))

    def body(x, xs):
        gp, gkv = xs
        h = layers.rmsnorm(x, gp["norm1"], cfg.norm_eps)
        kv_cache = (gkv["k"], gkv["v"]) if gkv is not None else None
        out, new_kv = layers.attention(
            gp["self_attn"], h, cfg, positions=positions,
            cache=kv_cache, cache_index=cache_index,
        )
        x = x + out
        h = layers.rmsnorm(x, gp["normx"], cfg.norm_eps)
        out, _ = layers.attention(
            gp["cross_attn"], h, cfg, positions=positions, causal=False,
            kv=(enc_out, enc_out),
        )
        x = x + out
        h = layers.rmsnorm(x, gp["norm2"], cfg.norm_eps)
        x = x + layers.mlp(gp["mlp"], h, cfg)
        ys = {"k": new_kv[0], "v": new_kv[1]} if new_kv is not None else None
        return x, ys

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (params["dec_stack"], cache)
    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(body, x, xs)
    else:
        ys = []
        for i in range(cfg.n_layers):
            x, y = body(x, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        new_cache = (
            jax.tree.map(lambda *a: jnp.stack(a), *ys) if ys[0] is not None else None
        )
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(x, params["embed"])
    return logits, new_cache


def loss_fn(params: dict, batch: dict, cfg: ModelConfig):
    enc_out = encode(params, batch["frames"], cfg)
    logits, _ = decode(params, batch["tokens"], enc_out, cfg, mode="train")
    loss, nll = layers.xent_loss(logits, batch["labels"], batch.get("mask"), cfg.z_loss)
    return loss, {"nll": nll}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    return layers.init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return layers.kv_cache_specs(cfg, batch, max_len, cfg.n_layers, dtype)
