"""Unified decoder-only LM covering dense / MoE / SSM / hybrid families.

Layers are scanned in *groups* (``cfg.layer_group`` layers per scan step) so
the HLO is depth-independent; heterogeneous stacks (Jamba's 1-attn:7-mamba
period with alternating MoE) set ``layer_group`` to the period.  Leading
``first_dense`` layers (DeepSeek-MoE) are hoisted out of the scan.

All functions are pure; parameters are dicts declared via PSpec trees
(see models/layers.py) so the same declaration produces real params,
ShapeDtypeStructs (dry-run) and PartitionSpecs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import layers, mamba2, moe as moe_lib

# ---------------------------------------------------------------------------
# Param declaration
# ---------------------------------------------------------------------------


def _block_specs(cfg: ModelConfig, layer_idx: int) -> dict:
    kind = cfg.layer_kind(layer_idx)
    mk = cfg.mlp_kind(layer_idx)
    p = {
        "norm1": layers.rmsnorm_spec(cfg.d_model),
        "mixer": layers.attention_specs(cfg) if kind == "attn" else mamba2.ssm_specs(cfg),
    }
    if mk == "moe":
        p["norm2"] = layers.rmsnorm_spec(cfg.d_model)
        p["mlp"] = moe_lib.moe_specs(cfg)
    elif (cfg.dense_d_ff or cfg.d_ff) > 0:
        p["norm2"] = layers.rmsnorm_spec(cfg.d_model)
        p["mlp"] = layers.mlp_specs(cfg, d_ff=(cfg.dense_d_ff or cfg.d_ff))
    # d_ff == 0 (mamba2): mixer-only block, no FFN
    return p


def _plan(cfg: ModelConfig):
    """(prefix_layer_indices, n_scan_groups, group_layer_indices)."""
    prefix = list(range(cfg.first_dense))
    rest = cfg.n_layers - cfg.first_dense
    g = cfg.layer_group if cfg.scan_layers else rest
    assert rest % g == 0, (cfg.n_layers, cfg.first_dense, g)
    n_groups = rest // g
    group_idx = [cfg.first_dense + j for j in range(g)]
    # periodicity check: every group must share the prototype structure
    for gi in range(n_groups):
        for j in range(g):
            i = cfg.first_dense + gi * g + j
            proto = cfg.first_dense + j
            assert cfg.layer_kind(i) == cfg.layer_kind(proto), (i, proto)
            assert cfg.mlp_kind(i) == cfg.mlp_kind(proto), (i, proto)
    return prefix, n_groups, group_idx


def decoder_specs(cfg: ModelConfig) -> dict:
    prefix, n_groups, group_idx = _plan(cfg)
    specs = {
        "embed": layers.embedding_spec(cfg),
        "final_norm": layers.rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = layers.PSpec(
            (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), std=cfg.d_model ** -0.5
        )
    for i in prefix:
        specs[f"prefix_{i}"] = _block_specs(cfg, i)
    group = {f"sub{j}": _block_specs(cfg, i) for j, i in enumerate(group_idx)}
    specs["stack"] = layers.stack_specs(group, n_groups)
    return specs


# ---------------------------------------------------------------------------
# Caches (KV for attention layers, conv+state for SSM layers)
# ---------------------------------------------------------------------------


def _cache_plan(cfg: ModelConfig):
    prefix, n_groups, group_idx = _plan(cfg)
    pre_attn = [i for i in prefix if cfg.layer_kind(i) == "attn"]
    pre_ssm = [i for i in prefix if cfg.layer_kind(i) == "ssm"]
    grp_attn = [j for j, i in enumerate(group_idx) if cfg.layer_kind(i) == "attn"]
    grp_ssm = [j for j, i in enumerate(group_idx) if cfg.layer_kind(i) == "ssm"]
    return pre_attn, pre_ssm, grp_attn, grp_ssm, n_groups


def _cache_struct(cfg: ModelConfig, batch: int, max_len: int, dtype, abstract: bool):
    pre_attn, pre_ssm, grp_attn, grp_ssm, n_groups = _cache_plan(cfg)
    mk_kv = layers.kv_cache_specs if abstract else layers.init_kv_cache
    mk_ssm = mamba2.ssm_cache_specs if abstract else mamba2.init_ssm_cache
    cache: dict = {}
    if pre_attn:
        cache["prefix_kv"] = mk_kv(cfg, batch, max_len, len(pre_attn), dtype)
    if pre_ssm:
        cache["prefix_ssm"] = mk_ssm(cfg, batch, len(pre_ssm), dtype)
    if grp_attn:
        kv = mk_kv(cfg, batch, max_len, n_groups * len(grp_attn), dtype)
        cache["scan_kv"] = jax.tree.map(
            lambda a: (
                jax.ShapeDtypeStruct((n_groups, len(grp_attn), *a.shape[1:]), a.dtype)
                if abstract
                else a.reshape(n_groups, len(grp_attn), *a.shape[1:])
            ),
            kv,
        )
    if grp_ssm:
        ssm = mk_ssm(cfg, batch, n_groups * len(grp_ssm), dtype)
        cache["scan_ssm"] = jax.tree.map(
            lambda a: (
                jax.ShapeDtypeStruct((n_groups, len(grp_ssm), *a.shape[1:]), a.dtype)
                if abstract
                else a.reshape(n_groups, len(grp_ssm), *a.shape[1:])
            ),
            ssm,
        )
    return cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    return _cache_struct(cfg, batch, max_len, dtype, abstract=False)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return _cache_struct(cfg, batch, max_len, dtype, abstract=True)


def cache_partition_specs(cfg: ModelConfig, cache) -> dict:
    """PartitionSpecs matching the cache pytree under current rules."""

    def kv_spec(extra):
        return {
            "k": sharding.spec(*extra, *layers.KV_CACHE_AXES),
            "v": sharding.spec(*extra, *layers.KV_CACHE_AXES),
        }

    def ssm_spec(extra):
        return {
            k: sharding.spec(*extra, *mamba2.SSM_CACHE_AXES[k]) for k in ("conv", "state")
        }

    out = {}
    if "prefix_kv" in cache:
        out["prefix_kv"] = kv_spec(())
    if "prefix_ssm" in cache:
        out["prefix_ssm"] = ssm_spec(())
    if "scan_kv" in cache:
        out["scan_kv"] = kv_spec((None,))
    if "scan_ssm" in cache:
        out["scan_ssm"] = ssm_spec((None,))
    return out


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _apply_block(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    layer_idx: int,
    positions: jax.Array,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]],
    ssm_cache: Optional[dict],
    cache_index,
    remat: bool = False,
):
    if remat:
        if cfg.remat_policy == "save_comm":
            # keep the post-all-reduce block outputs: the backward pass then
            # skips re-running the 2 forward TP all-reduces per layer
            policy = jax.checkpoint_policies.save_only_these_names(
                "mixer_out", "mlp_out"
            )
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        fn = jax.checkpoint(
            lambda p, y: _apply_block(
                p, y, cfg, layer_idx, positions, kv_cache, ssm_cache, cache_index
            ),
            policy=policy,
        )
        return fn(params, x)
    kind = cfg.layer_kind(layer_idx)
    mk = cfg.mlp_kind(layer_idx)
    h = layers.rmsnorm(x, params["norm1"], cfg.norm_eps)
    new_kv = new_ssm = None
    if kind == "attn":
        out, new_kv = layers.attention(
            params["mixer"], h, cfg, positions=positions,
            cache=kv_cache, cache_index=cache_index,
        )
    else:
        out, new_ssm = mamba2.ssm_block(params["mixer"], h, cfg, cache=ssm_cache)
    out = checkpoint_name(out, "mixer_out")
    x = x + out
    metrics = {}
    if "mlp" in params:
        h = layers.rmsnorm(x, params["norm2"], cfg.norm_eps)
        if mk == "moe":
            out, metrics = moe_lib.moe(params["mlp"], h, cfg)
        else:
            out = layers.mlp(params["mlp"], h, cfg)
        out = checkpoint_name(out, "mlp_out")
        x = x + out
    return x, new_kv, new_ssm, metrics


def forward(
    params: dict,
    tokens: Optional[jax.Array],
    cfg: ModelConfig,
    *,
    embeds: Optional[jax.Array] = None,   # (b, n_front, d) modality-stub embeddings
    cache: Optional[dict] = None,
    cache_index=0,
    positions: Optional[jax.Array] = None,
    mode: str = "train",                  # train | prefill | decode
):
    """Returns (logits, new_cache, metrics)."""
    prefix, n_groups, group_idx = _plan(cfg)
    pre_attn, pre_ssm, grp_attn, grp_ssm, _ = _cache_plan(cfg)

    parts = []
    if embeds is not None:
        parts.append(embeds.astype(params["embed"].dtype))
    if tokens is not None:
        parts.append(layers.embed(tokens, params["embed"]))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = sharding.shard(x, "batch", "seq", "act_embed")
    b, t = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))

    new_cache: dict = {}
    metrics_acc = []

    # ---- prefix (unscanned) blocks -------------------------------------
    for slot, i in enumerate(prefix):
        kv = None
        if cache is not None and i in pre_attn:
            j = pre_attn.index(i)
            kv = (cache["prefix_kv"]["k"][j], cache["prefix_kv"]["v"][j])
        ssm = None
        if cache is not None and i in pre_ssm:
            j = pre_ssm.index(i)
            ssm = {k: cache["prefix_ssm"][k][j] for k in ("conv", "state")}
        x, nkv, nssm, m = _apply_block(
            params[f"prefix_{i}"], x, cfg, i, positions, kv, ssm, cache_index,
            remat=cfg.remat and mode == "train",
        )
        if nkv is not None:
            acc = new_cache.setdefault("prefix_kv", {"k": [], "v": []})
            acc["k"].append(nkv[0])
            acc["v"].append(nkv[1])
        if nssm is not None:
            acc = new_cache.setdefault("prefix_ssm", {"conv": [], "state": []})
            for k in ("conv", "state"):
                acc[k].append(nssm[k])
        if m:
            metrics_acc.append(m)

    for key in ("prefix_kv", "prefix_ssm"):
        if key in new_cache:
            new_cache[key] = {k: jnp.stack(v) for k, v in new_cache[key].items()}

    # ---- scanned stack ----------------------------------------------------
    def group_body(x, xs):
        gp, gkv, gssm = xs
        out_kv = {"k": [], "v": []}
        out_ssm = {"conv": [], "state": []}
        gmetrics = []
        xg = x
        for j, i in enumerate(group_idx):
            kv = None
            if gkv is not None and j in grp_attn:
                a = grp_attn.index(j)
                kv = (gkv["k"][a], gkv["v"][a])
            ssm = None
            if gssm is not None and j in grp_ssm:
                a = grp_ssm.index(j)
                ssm = {k: gssm[k][a] for k in ("conv", "state")}
            xg, nkv, nssm, m = _apply_block(
                gp[f"sub{j}"], xg, cfg, i, positions, kv, ssm, cache_index,
                remat=cfg.remat and mode == "train",
            )
            if nkv is not None:
                out_kv["k"].append(nkv[0])
                out_kv["v"].append(nkv[1])
            if nssm is not None:
                for k in ("conv", "state"):
                    out_ssm[k].append(nssm[k])
            if m:
                gmetrics.append(m)
        ys = {}
        if out_kv["k"]:
            ys["kv"] = {k: jnp.stack(v) for k, v in out_kv.items()}
        if out_ssm["conv"]:
            ys["ssm"] = {k: jnp.stack(v) for k, v in out_ssm.items()}
        if gmetrics:
            ys["metrics"] = {
                k: jnp.mean(jnp.stack([mm[k] for mm in gmetrics])) for k in gmetrics[0]
            }
        return xg, ys

    body = group_body  # remat is applied per-block inside _apply_block

    xs = (
        params["stack"],
        cache.get("scan_kv") if cache is not None else None,
        cache.get("scan_ssm") if cache is not None else None,
    )
    x, ys = jax.lax.scan(body, x, xs)
    if "kv" in ys:
        new_cache["scan_kv"] = ys["kv"]
    if "ssm" in ys:
        new_cache["scan_ssm"] = ys["ssm"]
    if "metrics" in ys:
        metrics_acc.append({k: jnp.mean(v) for k, v in ys["metrics"].items()})

    # ---- head ------------------------------------------------------------
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed(x, table)

    metrics = {}
    for m in metrics_acc:
        for k, v in m.items():
            metrics[k] = metrics.get(k, 0.0) + v / len(metrics_acc)
    return logits, (new_cache if cache is not None else None), metrics


# ---------------------------------------------------------------------------
# Loss / serve entry points
# ---------------------------------------------------------------------------


def loss_fn(params: dict, batch: dict, cfg: ModelConfig):
    """batch: tokens (b,t) int32, labels (b,t), optional mask, optional embeds."""
    embeds = batch.get("embeds")
    logits, _, metrics = forward(params, batch["tokens"], cfg, embeds=embeds, mode="train")
    labels = batch["labels"]
    if embeds is not None:
        # loss only on the text positions (modality embeds carry no labels)
        logits = logits[:, embeds.shape[1]:, :]
    loss, nll = layers.xent_loss(logits, labels, batch.get("mask"), cfg.z_loss)
    for k, v in metrics.items():
        if k.startswith("moe_") and not k.endswith("overflow"):
            loss = loss + v
    metrics["nll"] = nll
    return loss, metrics


def prefill(params: dict, tokens, cfg: ModelConfig, cache, *, embeds=None):
    logits, new_cache, _ = forward(
        params, tokens, cfg, embeds=embeds, cache=cache, cache_index=0, mode="prefill"
    )
    return logits[:, -1:, :], new_cache


def decode_step(params: dict, tokens, cfg: ModelConfig, cache, index):
    """tokens: (b, 1) current token; index: scalar — tokens already in cache."""
    b = tokens.shape[0]
    positions = jnp.broadcast_to(index[None, None], (b, 1)) if jnp.ndim(index) == 0 else index
    logits, new_cache, _ = forward(
        params, tokens, cfg, cache=cache, cache_index=index, positions=positions, mode="decode"
    )
    return logits, new_cache
