"""Model facade: binds a ModelConfig to init/loss/serve entry points and
produces dry-run input specs for every (arch x shape) cell.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import encdec, layers, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- parameters ------------------------------------------------------
    def specs(self) -> dict:
        if self.cfg.is_encdec:
            return encdec.encdec_specs(self.cfg)
        return transformer.decoder_specs(self.cfg)

    def init(self, key: jax.Array, dtype=jnp.float32) -> dict:
        return layers.init_params(key, self.specs(), dtype)

    def abstract_params(self, dtype=jnp.bfloat16) -> dict:
        return layers.abstract_params(self.specs(), dtype)

    def param_partition_specs(self, extra_leading=()):
        return layers.param_partition_specs(self.specs(), extra_leading)

    def param_count(self) -> int:
        return layers.count_params(self.specs())

    # ---- training ----------------------------------------------------------
    def loss_fn(self, params: dict, batch: dict):
        if self.cfg.is_encdec:
            return encdec.loss_fn(params, batch, self.cfg)
        return transformer.loss_fn(params, batch, self.cfg)

    # ---- serving -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32):
        if self.cfg.is_encdec:
            return encdec.init_cache(self.cfg, batch, max_len, dtype)
        return transformer.init_cache(self.cfg, batch, max_len, dtype)

    def abstract_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        if self.cfg.is_encdec:
            return encdec.abstract_cache(self.cfg, batch, max_len, dtype)
        return transformer.abstract_cache(self.cfg, batch, max_len, dtype)

    def cache_partition_specs(self, cache):
        if self.cfg.is_encdec:
            return {
                "k": sharding.spec(*layers.KV_CACHE_AXES),
                "v": sharding.spec(*layers.KV_CACHE_AXES),
            }
        return transformer.cache_partition_specs(self.cfg, cache)

    def prefill(self, params, batch: dict, cache):
        cfg = self.cfg
        if cfg.is_encdec:
            enc_out = encdec.encode(params, batch["frames"], cfg)
            logits, new_cache = encdec.decode(
                params, batch["tokens"], enc_out, cfg, cache=cache, cache_index=0, mode="prefill"
            )
            return logits[:, -1:, :], {"kv": new_cache, "enc_out": enc_out}
        return transformer.prefill(
            params, batch.get("tokens"), cfg, cache, embeds=batch.get("embeds")
        )

    def decode_step(self, params, batch: dict, cache, index):
        cfg = self.cfg
        if cfg.is_encdec:
            b = batch["tokens"].shape[0]
            positions = jnp.broadcast_to(jnp.asarray(index)[None, None], (b, 1))
            logits, new_kv = encdec.decode(
                params, batch["tokens"], batch["enc_out"], cfg,
                cache=cache, cache_index=index, positions=positions, mode="decode",
            )
            return logits, new_kv
        return transformer.decode_step(params, batch["tokens"], cfg, cache, index)

    # ---- dry-run input declarations ---------------------------------------
    def input_specs(self, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for one dry-run cell (no allocation)."""
        cfg = self.cfg
        b = shape.global_batch
        i32 = jnp.int32

        if shape.kind == "train":
            t = shape.seq_len
            if cfg.family == "vlm":
                nf = cfg.n_frontend_tokens
                return {
                    "embeds": jax.ShapeDtypeStruct((b, nf, cfg.d_model), dtype),
                    "tokens": jax.ShapeDtypeStruct((b, t - nf), i32),
                    "labels": jax.ShapeDtypeStruct((b, t - nf), i32),
                }
            if cfg.is_encdec:
                return {
                    "frames": jax.ShapeDtypeStruct((b, cfg.n_frontend_tokens, cfg.d_model), dtype),
                    "tokens": jax.ShapeDtypeStruct((b, t), i32),
                    "labels": jax.ShapeDtypeStruct((b, t), i32),
                }
            return {
                "tokens": jax.ShapeDtypeStruct((b, t), i32),
                "labels": jax.ShapeDtypeStruct((b, t), i32),
            }

        if shape.kind == "prefill":
            t = shape.seq_len
            out = {"cache": self.abstract_cache(b, t, dtype)}
            if cfg.family == "vlm":
                nf = cfg.n_frontend_tokens
                out["embeds"] = jax.ShapeDtypeStruct((b, nf, cfg.d_model), dtype)
                out["tokens"] = jax.ShapeDtypeStruct((b, t - nf), i32)
            elif cfg.is_encdec:
                out["frames"] = jax.ShapeDtypeStruct((b, cfg.n_frontend_tokens, cfg.d_model), dtype)
                out["tokens"] = jax.ShapeDtypeStruct((b, t), i32)
            else:
                out["tokens"] = jax.ShapeDtypeStruct((b, t), i32)
            return out

        # decode: one new token against a cache of shape.seq_len
        out = {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache": self.abstract_cache(b, shape.seq_len, dtype),
            "index": jax.ShapeDtypeStruct((), i32),
        }
        if cfg.is_encdec:
            out["enc_out"] = jax.ShapeDtypeStruct((b, cfg.n_frontend_tokens, cfg.d_model), dtype)
        return out

    def input_partition_specs(self, shape: ShapeSpec, inputs: dict) -> dict:
        """PartitionSpecs matching input_specs() under the current rules."""
        cfg = self.cfg
        out = {}
        for k, v in inputs.items():
            if k in ("tokens", "labels", "mask"):
                out[k] = sharding.spec("batch", "seq") if jax.tree.leaves(v) else None
            elif k == "embeds":
                out[k] = sharding.spec("batch", "seq", "act_embed")
            elif k in ("frames", "enc_out"):
                out[k] = sharding.spec("batch", "frames", "act_embed")
            elif k == "index":
                out[k] = sharding.spec()
            elif k == "cache":
                if cfg.is_encdec:
                    out[k] = {
                        "k": sharding.spec(*layers.KV_CACHE_AXES),
                        "v": sharding.spec(*layers.KV_CACHE_AXES),
                    }
                else:
                    out[k] = transformer.cache_partition_specs(cfg, v)
            else:
                raise KeyError(k)
        return out


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
