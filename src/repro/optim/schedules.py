"""Learning-rate schedules (paper §3: 1000-step warmup, cosine to 5% peak).

``peak_lr`` / ``warmup`` may be Python scalars OR traced 0-d arrays.  The
trainer passes them as arrays (the state's ``hparams`` leaf) so sweeps over
lr share one executable; ``total`` stays static (it is a schedule-shape
constant, part of the trainer's static signature).  Caveat: under jit the
two forms can differ by ~1 ulp — XLA constant-folds a Python-scalar
``warmup`` (divide -> multiply-by-reciprocal) but keeps a traced operand
as a true divide — so traced-vs-traced runs are mutually consistent while
traced-vs-baked is only equal to float rounding.
"""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr, warmup, total: int, final_ratio: float = 0.05):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    # cosine from end of warmup to `total`
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_ratio + (1.0 - final_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return peak_lr * warm * cos
