"""Learning-rate schedules (paper §3: 1000-step warmup, cosine to 5% peak)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int, final_ratio: float = 0.05):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    # cosine from end of warmup to `total`
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_ratio + (1.0 - final_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return peak_lr * warm * cos
