from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm  # noqa: F401
from repro.optim.schedules import warmup_cosine  # noqa: F401
