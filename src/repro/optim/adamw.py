"""AdamW with decoupled weight decay + global-norm clipping.

Pure pytree functions (no optax dependency).  The elementwise update can be
routed through the fused Pallas kernel (``repro.kernels.fused_adamw``) via
``use_kernel=True`` — on TPU this fuses 6 HBM round-trips into one pass.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def adamw_init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_adamw_state(params):
    return {
        "m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    params,
    grads,
    state,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.99,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    use_kernel: bool = False,
) -> Tuple:
    """One AdamW step. Moments in fp32; params keep their dtype.

    ``lr`` / ``weight_decay`` may be Python scalars or traced 0-d arrays on
    the default (jnp) path — the trainer passes traced ``hparams`` so
    lr-sweep cells share executables.  ``b1``/``b2``/``eps`` stay static —
    they are not sweep axes and ``b1 ** c`` folds at compile time.  The
    ``use_kernel=True`` Pallas path still requires a STATIC
    ``weight_decay`` (the kernel closes over it rather than reading the
    scalars operand); route it through ``k_ops`` scalars before enabling
    the fused kernel on the trainer path.
    """
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    if use_kernel:
        from repro.kernels.fused_adamw import ops as k_ops

        def upd(p, g, m, v):
            return k_ops.fused_adamw(
                p, g, m, v, lr=lr, b1=b1, b2=b2, eps=eps,
                weight_decay=weight_decay, bc1=bc1, bc2=bc2,
            )
    else:

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1.0 - b1) * g32
            v = b2 * v + (1.0 - b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return p, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
