"""Logical-axis sharding rules.

Models annotate tensors with *logical* axis names ("batch", "heads", ...).
A ``Rules`` mapping — chosen by the launcher per (mesh, workload) — binds
logical names to mesh axis names.  This keeps model code mesh-agnostic while
letting the dry-run / trainer pick DP/FSDP/TP/EP/SP layouts per workload.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

# logical axis -> mesh axis (or None = replicated) ------------------------
_RULES: contextvars.ContextVar[dict] = contextvars.ContextVar("sharding_rules", default={})

# Default layout: DP over "data", TP over "model", DiLoCo replicas over
# "replica" (bound to the pod axis on the production mesh).
DEFAULT_RULES = {
    "replica": "replica",
    "batch": "data",
    "seq": None,            # sequence sharding off by default (on for long decode)
    "embed": "data",        # FSDP: shard the embed dim of weights over data
    "act_embed": None,      # activation feature axis (kept distinct from weights)
    "heads": "model",
    "kv_heads": None,       # kv=8 < 16-way model axis on most assigned archs
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "expert_cap": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "layers": None,
    "frames": None,
    "kv_seq": None,         # KV-cache sequence axis (sequence-parallel decode)
    "groups": "data",       # MoE dispatch groups follow the batch
}


_MESH: contextvars.ContextVar = contextvars.ContextVar("sharding_mesh", default=None)


@contextlib.contextmanager
def set_mesh(mesh):
    """Version-portable ``jax.set_mesh``: context manager activating ``mesh``.

    Newer jax exposes ``jax.set_mesh``; on older versions the Mesh object is
    itself the context manager that binds the ambient mesh.  The active mesh
    is also recorded so mesh-aware helpers (``current_mesh``, checkpoint
    restore's sharded ``device_put``) can find it.
    """
    token = _MESH.set(mesh)
    try:
        if hasattr(jax, "set_mesh"):
            with jax.set_mesh(mesh):
                yield mesh
        else:
            with mesh:
                yield mesh
    finally:
        _MESH.reset(token)


def current_mesh():
    """The mesh activated by the innermost ``set_mesh`` (None outside)."""
    return _MESH.get()


def tree_named(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree.

    ``jax.jit``'s in/out_shardings require concrete Shardings (bare
    PartitionSpecs are only accepted on newer jax with an ambient mesh).
    """
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def current_rules() -> dict:
    r = _RULES.get()
    return r if r else {}


@contextlib.contextmanager
def use_rules(rules: Optional[dict]):
    """Bind logical->mesh rules for the enclosed region (None = no sharding)."""
    token = _RULES.set(dict(rules) if rules else {})
    try:
        yield
    finally:
        _RULES.reset(token)


def spec(*logical_axes: Optional[str]) -> P:
    """PartitionSpec for the given logical axes under the current rules."""
    rules = current_rules()
    return P(*(rules.get(a) if a is not None else None for a in logical_axes))


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the current rules' layout. No-op when rules unset."""
    rules = current_rules()
    if not rules:
        return x
    assert x.ndim == len(logical_axes), (x.shape, logical_axes)
    s = spec(*logical_axes)
    if all(a is None for a in s):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, s)
    except Exception:
        # No ambient mesh (e.g. plain CPU unit test) — constraints are advisory.
        return x


def tree_constrain(tree, specs):
    """with_sharding_constraint over a pytree, skipping all-None specs and
    degrading to a no-op when no mesh is ambient (plain CPU tests)."""

    def one(x, s):
        if all(a is None for a in s):
            return x
        try:
            return jax.lax.with_sharding_constraint(x, s)
        except Exception:
            return x

    import jax.sharding as js

    return jax.tree.map(one, tree, specs,
                        is_leaf=lambda v: isinstance(v, js.PartitionSpec))


def tree_spec(logical_tree):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: spec(*axes),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x),
    )
