"""Batched serving driver: prefill a batch of prompts, then decode greedily.

The paper is a training paper, so serving exists to exercise the
decode/prefill cells of the assigned shape grid end-to-end on CPU with
reduced configs (the full configs are exercised by the dry-run).

  PYTHONPATH=src python -m repro.launch.serve --arch tiny-t1 --batch 4 \
      --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import build_model


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-t1")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def run_serve(args, *, quiet=False) -> dict:
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    # independent streams: reusing one key would correlate the params with
    # the prompt tokens and the vision/audio frontend embeddings
    k_params, k_tokens, k_embeds, k_frames = jax.random.split(
        jax.random.PRNGKey(args.seed), 4)
    params = model.init(k_params)
    max_len = args.prompt_len + args.gen + (cfg.n_frontend_tokens if cfg.family == "vlm" else 0)

    batch = {"tokens": jax.random.randint(k_tokens, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(k_embeds, (args.batch, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(k_frames, (args.batch, cfg.n_frontend_tokens, cfg.d_model)) * 0.02

    cache = model.init_cache(args.batch, max_len)
    t0 = time.time()
    prefill = jax.jit(lambda p, b, c: model.prefill(p, b, c))
    logits, cache = prefill(params, batch, cache)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0
    prefill_tok_s = args.batch * args.prompt_len / max(prefill_s, 1e-9)

    if cfg.is_encdec:
        enc_out, cache = cache["enc_out"], cache["kv"]
    npast = args.prompt_len + (cfg.n_frontend_tokens if cfg.family == "vlm" else 0)

    decode = jax.jit(
        lambda p, b, c, i: model.decode_step(p, b, c, i)
    )
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    outs = [tok]
    t0 = time.time()
    for i in range(args.gen):
        db = {"tokens": tok}
        if cfg.is_encdec:
            db["enc_out"] = enc_out
        logits, cache = decode(params, db, cache, jnp.asarray(npast + i))
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0
    decode_tok_s = args.gen * args.batch / max(decode_s, 1e-9)
    gen = jnp.concatenate(outs, axis=1)
    if not quiet:
        print(f"prefill {args.batch}x{args.prompt_len}: {prefill_s:.2f}s "
              f"({prefill_tok_s:.1f} tok/s)")
        print(f"decoded {args.gen} tokens x {args.batch} streams in {decode_s:.2f}s "
              f"({decode_tok_s:.1f} tok/s)")
        print("sample:", gen[0, :16].tolist())
    return {
        "prefill_s": prefill_s,
        "prefill_tok_s": prefill_tok_s,
        "decode_s": decode_s,
        "decode_tok_s": decode_tok_s,
        "prompt_tokens": batch["tokens"],
        "tokens": gen,
    }


def main():
    run_serve(build_argparser().parse_args())


if __name__ == "__main__":
    main()
