import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without
real hardware.

For every (architecture x input-shape) cell, ``.lower().compile()`` must
succeed on BOTH production meshes:

  * single-pod  (16, 16)      ("data", "model")          — 256 chips
  * multi-pod   (2, 16, 16)   ("pod", "data", "model")   — 512 chips

Train cells lower the DiLoCo ``train_step`` (fused inner+outer executable —
the cross-pod outer all-reduce is in the HLO); decode/prefill cells lower
``serve_step``.

Cost derivation (see EXPERIMENTS.md §Roofline for caveats):
  * deliverable compile keeps the production scan-over-layers (fast compile,
    authoritative memory_analysis) — but XLA cost_analysis counts scan
    bodies ONCE, so per-step flops/collectives are derived from two shallow
    *probe* compiles (1-group and 2-group unrolled stacks) and extrapolated:
        total = probe1 + (n_groups - 1) * (probe2 - probe1)
    This keeps every number HLO-derived (not hand-modelled) while staying
    compilable on one CPU core.
  * decode cells unroll fully (single token — small HLO), costs are direct.
  * the memory term additionally gets an analytic TPU-HBM-traffic estimate
    (CPU-XLA 'bytes accessed' reflects CPU fusion, not TPU).

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs import (
    ASSIGNED_ARCHS,
    DiLoCoConfig,
    OptimizerConfig,
    TrainConfig,
    cells,
    get_config,
    shape_by_name,
)
from repro.core.diloco import make_trainer
from repro.launch import roofline as rl
from repro.launch.costs import _ssd_fwd_flops, analytic_costs
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models import build_model


def _abstract_leading(tree, m: int):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct((m, *s.shape), s.dtype), tree)


def _ssd_flops_correction(cfg, shape, multiplier: float) -> float:
    """Flops hidden inside SSD lax.scan trips beyond the first (total)."""
    if cfg.ssm_state == 0 or shape.kind == "decode":
        return 0.0
    nc = max(shape.seq_len // min(cfg.ssm_chunk, shape.seq_len), 1)
    n_ssm = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "ssm")
    total = shape.global_batch * n_ssm * _ssd_fwd_flops(cfg, shape.seq_len)
    return multiplier * total * (nc - 1) / nc


def _lower_cell(cfg, shape, mesh, multi_pod, sync_every, dtype, compression):
    """Lower train_step / serve_step for one cell. Returns (lowered, extra)."""
    model = build_model(cfg)
    m_replicas = 2 if multi_pod else 1
    if shape.kind == "train":
        tokens_per_step = shape.global_batch * shape.seq_len
        tcfg = TrainConfig(
            global_batch_tokens=tokens_per_step, seq_len=shape.seq_len,
            steps=max(int(20 * cfg.param_count() / tokens_per_step), 1),
        )
        dcfg = DiLoCoConfig(num_replicas=m_replicas, sync_every=sync_every,
                            compression=compression)
        trainer = make_trainer(model, dcfg, OptimizerConfig(), tcfg)
        state = trainer.abstract_state(dtype)
        per_replica = dataclasses.replace(shape, global_batch=shape.global_batch // m_replicas)
        batch = _abstract_leading(model.input_specs(per_replica, dtype), m_replicas)
        in_specs = (trainer.state_partition_specs(), trainer.batch_partition_specs(batch))
        lowered = jax.jit(
            trainer.train_step, in_shardings=in_specs, out_shardings=(in_specs[0], None)
        ).lower(state, batch)
        outer_lowered = jax.jit(
            trainer.outer_sync, in_shardings=(in_specs[0],), out_shardings=in_specs[0]
        ).lower(state)
        return lowered, outer_lowered
    params = model.abstract_params(dtype)
    inputs = model.input_specs(shape, dtype)
    pspecs = model.param_partition_specs()
    ispecs = model.input_partition_specs(shape, inputs)
    if shape.kind == "prefill":

        def serve_step(p, inp):
            batch = {k: v for k, v in inp.items() if k != "cache"}
            return model.prefill(p, batch, inp["cache"])

    else:

        def serve_step(p, inp):
            batch = {k: v for k, v in inp.items() if k not in ("cache", "index")}
            return model.decode_step(p, batch, inp["cache"], inp["index"])

    lowered = jax.jit(
        serve_step, in_shardings=(pspecs, ispecs), out_shardings=None
    ).lower(params, inputs)
    return lowered, None


def _probe_cfg(cfg, n_groups_wanted: int):
    """Shallow unrolled variant with `n_groups_wanted` scan groups of layers."""
    g = cfg.layer_group
    n_layers = cfg.first_dense + n_groups_wanted * g
    enc = min(cfg.encoder_layers, n_groups_wanted) if cfg.encoder_layers else 0
    return cfg.replace(n_layers=n_layers, encoder_layers=enc, scan_layers=False)


def _costs_of(compiled, txt=None) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    txt = txt if txt is not None else compiled.as_text()
    # bf16-native payload counting (see roofline.collective_traffic docstring)
    traffic = rl.collective_traffic(txt, f32_as_bf16=True)
    raw = rl.collective_traffic(txt, f32_as_bf16=False)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": traffic["total_bytes"],
        "coll_raw_f32": raw["total_bytes"],
        "traffic": traffic,
    }


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    sync_every: int = 30,
    dtype=jnp.bfloat16,
    rule_overrides=None,
    cfg_overrides=None,
    dump_hlo: str = "",
    compression: str = "none",
    probes: bool = True,
) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    m_replicas = 2 if multi_pod else 1
    rules = rules_for(
        arch, shape.kind, multi_pod=multi_pod, global_batch=shape.global_batch,
        overrides=rule_overrides,
    )
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6.0 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * n_active * shape.global_batch * (
            shape.seq_len if shape.kind == "prefill" else 1
        )
    rec = {
        "arch": arch, "shape": shape_name, "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "kind": shape.kind, "replicas": m_replicas,
        "params_b": cfg.param_count() / 1e9, "active_params_b": n_active / 1e9,
        "rules": {k: str(v) for k, v in rules.items()},
    }

    with sharding.set_mesh(mesh), sharding.use_rules(rules):
        # ---- deliverable compile (production config) ---------------------
        deliver_cfg = cfg if shape.kind != "decode" else cfg.replace(scan_layers=False)
        t0 = time.time()
        lowered, outer_lowered = _lower_cell(
            deliver_cfg, shape, mesh, multi_pod, sync_every, dtype, compression
        )
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        txt = compiled.as_text()
        rec["memory"] = rl.memory_stats(compiled)
        deliver_costs = _costs_of(compiled, txt)
        rec["hlo_raw"] = {k: deliver_costs[k] for k in ("flops", "bytes", "coll")}
        if dump_hlo:
            with open(dump_hlo, "w") as f:
                f.write(txt)

        if outer_lowered is not None:
            oc = outer_lowered.compile()
            otraffic = rl.collective_traffic(oc.as_text())
            rec["outer_collectives"] = otraffic
            rec["outer_bytes_per_dev"] = otraffic["total_bytes"]
            rec["outer_bytes_amortized_per_step"] = otraffic["total_bytes"] / sync_every

        # ---- cost attribution -------------------------------------------
        if shape.kind == "decode" or not probes:
            flops_dev = deliver_costs["flops"]
            coll_dev = deliver_costs["coll"]
            bytes_dev = deliver_costs["bytes"]
            rec["cost_source"] = "hlo-direct"
        else:
            # two shallow probes -> per-group marginal cost -> extrapolate
            if cfg.is_encdec:
                n_groups = cfg.n_layers  # enc/dec stacks scale together (12/12)
            else:
                from repro.models.transformer import _plan

                _, n_groups, _ = _plan(cfg)
            t2 = time.time()
            p1_l, _ = _lower_cell(_probe_cfg(cfg, 1), shape, mesh, multi_pod,
                                  sync_every, dtype, compression)
            c1 = _costs_of(p1_l.compile())
            p2_l, _ = _lower_cell(_probe_cfg(cfg, 2), shape, mesh, multi_pod,
                                  sync_every, dtype, compression)
            c2 = _costs_of(p2_l.compile())
            rec["probe_s"] = round(time.time() - t2, 1)
            rec["probes"] = {"c1": {k: c1[k] for k in ("flops", "bytes", "coll")},
                             "c2": {k: c2[k] for k in ("flops", "bytes", "coll")},
                             "n_groups": n_groups}

            def extrap(key):
                body = max(c2[key] - c1[key], 0.0)
                return c1[key] + (n_groups - 1) * body

            flops_dev = extrap("flops")
            bytes_dev = extrap("bytes")
            coll_dev = extrap("coll")
            rec["cost_source"] = "hlo-probe-extrapolated"

        mult = 4.0 if shape.kind == "train" else 1.0
        ssd_corr = _ssd_flops_correction(cfg, shape, mult)
        if ssd_corr:
            flops_dev += ssd_corr / chips
            rec["ssd_flops_correction_per_dev"] = ssd_corr / chips

        rec["analytic"] = analytic_costs(cfg, shape, chips)
        roof = rl.Roofline(
            flops_per_dev=flops_dev,
            bytes_per_dev=min(bytes_dev, rec["analytic"]["bytes_per_dev"] * 4),
            collective_bytes_per_dev=coll_dev,
            chips=chips,
            model_flops_total=model_flops,
        )
        rec["hlo_bytes_per_dev"] = bytes_dev
        rec["analytic_bytes_per_dev"] = rec["analytic"]["bytes_per_dev"]
        rec["roofline"] = roof.as_dict()
        # multi-pod cells skip probes: roofline numbers valid on single-pod
        rec["roofline_valid"] = (shape.kind == "decode") or probes
        rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ASSIGNED_ARCHS) + ["all"], default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--sync-every", type=int, default=30)
    ap.add_argument("--compression", default="none", choices=["none", "int8"])
    ap.add_argument("--dump-hlo", default="")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--all", action="store_true")
    # ---- perf-iteration knobs (EXPERIMENTS.md §Perf) ---------------------
    ap.add_argument("--zero1", action="store_true",
                    help="ZeRO-1: replicate params over data, shard fp32 moments")
    ap.add_argument("--expert-cap-shard", action="store_true",
                    help="MoE: shard the capacity dim over model (defers the AR)")
    ap.add_argument("--remat-policy", default="", choices=["", "nothing", "save_comm"])
    ap.add_argument("--moe-group", type=int, default=0)
    ap.add_argument("--tag", default="", help="suffix for the result key")
    args = ap.parse_args()

    rule_overrides = {}
    if args.zero1:
        rule_overrides.update({"embed": None, "opt_embed": "data"})
    if args.expert_cap_shard:
        rule_overrides.update({"expert_cap": "model", "expert_ff": None})
    rule_overrides = rule_overrides or None
    cfg_overrides = {}
    if args.remat_policy:
        cfg_overrides["remat_policy"] = args.remat_policy
    if args.moe_group:
        cfg_overrides["moe_group_size"] = args.moe_group

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else [args.arch]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for mp in meshes:  # single-pod sweep first (roofline table), then multi-pod
        for arch in archs:
            for shape in cells(arch):
                if args.shape not in ("all", shape.name):
                    continue
                key = f"{arch}|{shape.name}|{'2x16x16' if mp else '16x16'}"
                if args.tag:
                    key += f"|{args.tag}"
                if results.get(key, {}).get("ok"):
                    print(f"[cached] {key}", flush=True)
                    continue
                print(f"[run] {key}", flush=True)
                try:
                    rec = run_cell(
                        arch, shape.name, mp,
                        sync_every=args.sync_every, compression=args.compression,
                        dump_hlo=args.dump_hlo,
                        rule_overrides=rule_overrides, cfg_overrides=cfg_overrides,
                        probes=not args.no_probes and not mp,  # roofline: single-pod
                    )
                except Exception as e:
                    rec = {"ok": False, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"  FAILED: {rec['error']}", flush=True)
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if rec.get("ok"):
                    r = rec["roofline"]
                    print(
                        f"  ok compile={rec['compile_s']}s probes={rec.get('probe_s','-')}s "
                        f"compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
                        f"coll={r['collective_s']*1e3:.2f}ms bn={r['bottleneck']}",
                        flush=True,
                    )

    n_ok = sum(1 for v in results.values() if v.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
