"""Scaling-law sweep driver: run an (N x M x H x B x sync-mode) grid.

The paper's headline contribution is that DiLoCo's eval loss and optimal
hyperparameters follow scaling laws in (N, M) that can be fit and
extrapolated (§6).  This driver produces the data those fits consume: it
expands a named ``SweepSpec`` grid (``repro.configs.sweeps``) into cells,
runs each cell on the compiled superstep engine via
``repro.launch.train.run_experiment``, and appends one record per cell to a
versioned, append-only JSONL ledger under ``results/``.

Fault tolerance is two-level:

* **cell-level**: a completed cell's ledger record is durable (fsync'd
  append); re-running the sweep skips every cell already in the ledger.
* **step-level**: each cell checkpoints into its own directory (the PR-2
  elastic checkpoint subsystem), so a cell killed mid-run resumes from its
  last checkpoint instead of step 0.

  PYTHONPATH=src python -m repro.launch.sweep --grid smoke
  PYTHONPATH=src python -m repro.launch.fit --ledger results/SWEEP_smoke.jsonl
"""
from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
import shutil
import time

from repro.configs import get_config, get_sweep
from repro.configs.sweeps import SweepSpec, default_lr
from repro.launch.train import ExperimentConfig, run_experiment
from repro.models import build_model

LEDGER_SCHEMA = 1


# ---------------------------------------------------------------------------
# Grid expansion
# ---------------------------------------------------------------------------


def _resolve_steps(sweep: SweepSpec, arch: str, batch_tokens: int) -> int:
    if sweep.steps:
        return sweep.steps
    n_params = build_model(get_config(arch)).param_count()
    return max(int(sweep.budget_mult * n_params / batch_tokens), sweep.min_steps)


def expand_grid(sweep: SweepSpec) -> list:
    """Cross product of the grid axes, normalized so equivalent cells get
    identical specs: dp ignores the M / H / outer-optimizer axes (emitted
    once per (arch, B) with M=1), streaming resolves its fragment count.
    Cheapest-first (by N then steps) so partial sweeps are useful."""
    cells = []
    seen = set()
    for arch in sweep.archs:
        for batch_tokens in sweep.batch_tokens:
            steps = _resolve_steps(sweep, arch, batch_tokens)
            lr = sweep.lr or default_lr(get_config(arch).d_model)
            for mode in sweep.modes:
                for m in sweep.replicas:
                    for h in sweep.sync_every:
                        spec = {
                            "arch": arch,
                            "mode": mode,
                            "m": m if mode != "dp" else 1,
                            "h": h if mode != "dp" else 1,
                            "batch_tokens": batch_tokens,
                            "seq_len": sweep.seq_len,
                            "steps": steps,
                            "lr": round(lr, 8),
                            "outer_lr": sweep.outer_lr if mode != "dp" else 0.0,
                            "outer_momentum": sweep.outer_momentum if mode != "dp" else 0.0,
                            "nesterov": sweep.nesterov if mode != "dp" else False,
                            "streaming_fragments": (
                                min(sweep.streaming_fragments, h)
                                if mode == "streaming" else 0
                            ),
                            "seed": sweep.seed,
                            "engine": sweep.engine,
                        }
                        cid = cell_id(spec)
                        if cid not in seen:  # dp collapses the M/H axes
                            seen.add(cid)
                            cells.append(spec)
    cells.sort(key=lambda s: (get_config(s["arch"]).d_model, s["steps"], s["m"]))
    return cells


def cell_id(spec: dict) -> str:
    """Stable content hash of a cell spec (independent of the sweep name, so
    identical cells dedupe across grids sharing a ledger)."""
    return hashlib.sha1(
        json.dumps(spec, sort_keys=True).encode()
    ).hexdigest()[:12]


def cell_config(sweep: SweepSpec, spec: dict, checkpoint_root: str) -> ExperimentConfig:
    """The ExperimentConfig that runs one grid cell, with its own
    checkpoint directory for step-level resume."""
    ckpt_dir = os.path.join(checkpoint_root, cell_id(spec)) if checkpoint_root else ""
    return ExperimentConfig(
        arch=spec["arch"],
        algorithm="dp" if spec["mode"] == "dp" else "diloco",
        engine=spec["engine"],
        replicas=spec["m"],
        sync_every=spec["h"],
        outer_lr=spec["outer_lr"],
        outer_momentum=spec["outer_momentum"],
        nesterov=spec["nesterov"],
        lr=spec["lr"],
        warmup=max(1, math.ceil(sweep.warmup_frac * spec["steps"])),
        batch_tokens=spec["batch_tokens"],
        seq_len=spec["seq_len"],
        steps=spec["steps"],
        seed=spec["seed"],
        compression="int8" if spec["mode"] == "int8" else "none",
        streaming_fragments=spec["streaming_fragments"],
        eval_batches=sweep.eval_batches,
        eval_seqs=sweep.eval_seqs,
        checkpoint_dir=ckpt_dir,
        checkpoint_every=sweep.checkpoint_every,
        resume=bool(ckpt_dir),
    )


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------


def read_ledger(path: str) -> dict:
    """Completed cells by id.  Append-only JSONL: a crash mid-append can
    leave one truncated trailing line — tolerate and drop it (the cell will
    simply re-run, resuming from its checkpoints)."""
    done = {}
    if not os.path.exists(path):
        return done
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue  # truncated tail from a killed writer
            if rec.get("schema") == LEDGER_SCHEMA and "cell" in rec:
                done[rec["cell"]] = rec
    return done


def _json_safe(obj):
    """Non-finite floats -> null: the stdlib's default NaN/Infinity tokens
    are invalid JSON and would make the ledger unparseable to strict
    consumers (jq, JSON.parse, ...)."""
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    return obj


def append_record(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(_json_safe(rec), allow_nan=False) + "\n")
        f.flush()
        os.fsync(f.fileno())


# ---------------------------------------------------------------------------
# Driving
# ---------------------------------------------------------------------------


def run_sweep(
    sweep: SweepSpec,
    ledger_path: str,
    checkpoint_root: str = "",
    *,
    max_cells: int = 0,
    force: bool = False,
    clean: bool = False,
    quiet: bool = False,
) -> list:
    """Run every grid cell not already in the ledger.

    Returns ``[{"cell", "spec", "skipped", "record"}, ...]`` in grid order.
    ``max_cells`` stops after that many cells actually ran (0 = no limit);
    ``clean`` removes a cell's checkpoint directory once its record is
    durable in the ledger.
    """
    cells = expand_grid(sweep)
    done = {} if force else read_ledger(ledger_path)
    out, ran = [], 0
    for i, spec in enumerate(cells):
        cid = cell_id(spec)
        if cid in done:
            if not quiet:
                print(f"[{i + 1}/{len(cells)}] {cid} skip (in ledger): {spec}")
            out.append({"cell": cid, "spec": spec, "skipped": True,
                        "record": done[cid]})
            continue
        if max_cells and ran >= max_cells:
            break
        t0 = time.time()
        config = cell_config(sweep, spec, checkpoint_root)
        result = run_experiment(config, quiet=True)
        rec = _json_safe({
            "schema": LEDGER_SCHEMA,
            "cell": cid,
            "sweep": sweep.name,
            "spec": spec,
            **result.to_record(),
        })
        append_record(ledger_path, rec)
        if clean and config.checkpoint_dir:
            shutil.rmtree(config.checkpoint_dir, ignore_errors=True)
        ran += 1
        if not quiet:
            resumed = f" (resumed@{result.start_step})" if result.start_step else ""
            print(f"[{i + 1}/{len(cells)}] {cid} eval={result.final_eval:.4f} "
                  f"sim={result.sim['wallclock']['total_s']:.2f}s "
                  f"({time.time() - t0:.1f}s){resumed}: {spec}", flush=True)
        out.append({"cell": cid, "spec": spec, "skipped": False, "record": rec})
    return out


def build_argparser():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--grid", default="smoke",
                    help="named SweepSpec from repro.configs.sweeps")
    ap.add_argument("--ledger", default="",
                    help="JSONL ledger path (default results/SWEEP_<grid>.jsonl)")
    ap.add_argument("--checkpoint-root", default="",
                    help="per-cell checkpoint root "
                         "(default results/sweep_<grid>_ckpt; 'none' disables)")
    ap.add_argument("--max-cells", type=int, default=0,
                    help="stop after running this many cells (0 = all)")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells even if already in the ledger")
    ap.add_argument("--clean", action="store_true",
                    help="delete a cell's checkpoints once its record is durable")
    return ap


def main():
    args = build_argparser().parse_args()
    sweep = get_sweep(args.grid)
    ledger = args.ledger or os.path.join("results", f"SWEEP_{sweep.name}.jsonl")
    ckpt_root = args.checkpoint_root or os.path.join(
        "results", f"sweep_{sweep.name}_ckpt")
    if ckpt_root == "none":
        ckpt_root = ""
    cells = expand_grid(sweep)
    print(f"sweep {sweep.name}: {len(cells)} cells -> {ledger}")
    results = run_sweep(sweep, ledger, ckpt_root,
                        max_cells=args.max_cells, force=args.force,
                        clean=args.clean)
    ran = sum(1 for r in results if not r["skipped"])
    print(f"done: {ran} ran, {sum(1 for r in results if r['skipped'])} skipped, "
          f"{len(cells) - len(results)} remaining")


if __name__ == "__main__":
    main()
