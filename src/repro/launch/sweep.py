"""Scaling-law sweep driver: run an (N x M x H x B x sync-mode) grid.

The paper's headline contribution is that DiLoCo's eval loss and optimal
hyperparameters follow scaling laws in (N, M) that can be fit and
extrapolated (§6).  This driver produces the data those fits consume: it
expands a named ``SweepSpec`` grid (``repro.configs.sweeps``) into cells,
runs them on the compiled superstep engine, and appends one record per cell
to a versioned, append-only JSONL ledger under ``results/``.

Execution is three-tier, fastest applicable path first:

* **stacked** — ``plan_groups`` partitions the ledger-incomplete cells into
  shape-compatible groups (same arch / B / seq_len / M / H / steps /
  sync-mode, differing only in lr / outer-lr / momentum / seed); each group
  of >= 2 runs as ONE vmapped, donated executable on
  ``repro.core.cellbatch.CellBatchEngine`` — per-cell results are
  bitwise-identical to the sequential path;
* **shared-executable** — singleton cells run sequentially via
  ``run_experiment``, but trainers/engines cache executables process-wide
  by static shape signature (``repro.core.jitcache``), so structurally
  identical cells compile exactly once;
* **persistent compilation cache** — the CLI enables
  ``results/.xla_cache`` (``repro.launch.xla_cache``), so *re-runs* and CI
  skip XLA compilation entirely.

Fault tolerance is two-level:

* **cell-level**: a completed cell's ledger record is durable (fsync'd
  append); re-running the sweep skips every cell already in the ledger.
* **step-level**: each *sequential* cell checkpoints into its own
  directory (the PR-2 elastic checkpoint subsystem), so a cell killed
  mid-run resumes from its last checkpoint instead of step 0.  Stacked
  groups trade this in: they do not checkpoint mid-run (a kill re-runs the
  group), and a cell that already has checkpoints is routed to the
  sequential path so its resume is honored.

  PYTHONPATH=src python -m repro.launch.sweep --grid smoke
  PYTHONPATH=src python -m repro.launch.fit --ledger results/SWEEP_smoke.jsonl
"""
from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
import shutil
import time
import warnings
from functools import lru_cache

import numpy as np

from repro.configs import get_config, get_sweep
from repro.configs.sweeps import SweepSpec, default_lr
from repro.core import faults, retry
from repro.core import sync as sync_lib
from repro.core.cellbatch import CellBatchEngine
from repro.launch.train import (
    ExperimentConfig,
    ExperimentResult,
    _eval_stats,
    make_run,
    run_experiment,
    simulate_cell,
)
from repro.models import build_model

LEDGER_SCHEMA = 1


# ---------------------------------------------------------------------------
# Grid expansion
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _arch_param_count(arch: str) -> int:
    """N for one arch.  ``param_count`` is a pure function of the config, so
    memoizing makes grid expansion O(archs) model builds instead of
    O(archs x batch axes) — `paper`-scale grids build each Chinchilla model
    once, not once per batch size."""
    return build_model(get_config(arch)).param_count()


def _resolve_steps(sweep: SweepSpec, arch: str, batch_tokens: int) -> int:
    if sweep.steps:
        return sweep.steps
    return max(
        int(sweep.budget_mult * _arch_param_count(arch) / batch_tokens),
        sweep.min_steps,
    )


# grid-mode name -> registered sync-strategy name.  Modes are strategy
# names, except the historical "diloco" spelling of the full-precision
# strategy; any newly registered strategy is a valid mode as-is.
MODE_STRATEGY = {"diloco": "full"}


def mode_strategy(mode: str) -> "sync_lib.SyncStrategy":
    """Default-option strategy instance for a grid mode (capability
    introspection: axis collapse, fragment clamp, sync spec)."""
    return sync_lib.get(MODE_STRATEGY.get(mode, mode))


def expand_grid(sweep: SweepSpec) -> list:
    """Cross product of the grid axes, normalized so equivalent cells get
    identical specs: strategies without an outer optimizer (dp) ignore the
    M / H / outer-optimizer axes (emitted once per (arch, B, lr, seed) with
    M=1), fragment-wise strategies resolve their fragment count.
    Cheapest-first (by N then steps) so partial sweeps are useful."""
    cells = []
    seen = set()
    strats = {mode: mode_strategy(mode) for mode in sweep.modes}
    for arch in sweep.archs:
        base_lr = sweep.lr or default_lr(get_config(arch).d_model)
        lrs = sweep.lrs or (base_lr,)
        outer_lrs = sweep.outer_lrs or (sweep.outer_lr,)
        seeds = sweep.seeds or (sweep.seed,)
        for batch_tokens in sweep.batch_tokens:
            steps = _resolve_steps(sweep, arch, batch_tokens)
            for mode in sweep.modes:
                outer = strats[mode].uses_outer_opt
                fragmented = strats[mode].num_fragments > 0
                for m in sweep.replicas:
                    for h in sweep.sync_every:
                        for lr in lrs:
                            for outer_lr in outer_lrs:
                                for seed in seeds:
                                    spec = {
                                        "arch": arch,
                                        "mode": mode,
                                        "m": m if outer else 1,
                                        "h": h if outer else 1,
                                        "batch_tokens": batch_tokens,
                                        "seq_len": sweep.seq_len,
                                        "steps": steps,
                                        "lr": round(lr, 8),
                                        "outer_lr": outer_lr if outer else 0.0,
                                        "outer_momentum": sweep.outer_momentum if outer else 0.0,
                                        "nesterov": sweep.nesterov if outer else False,
                                        "streaming_fragments": (
                                            min(sweep.streaming_fragments, h)
                                            if fragmented else 0
                                        ),
                                        "seed": seed,
                                        "engine": sweep.engine,
                                    }
                                    cid = cell_id(spec)
                                    if cid not in seen:  # dp collapses M/H/outer axes
                                        seen.add(cid)
                                        cells.append(spec)
    cells.sort(key=lambda s: (get_config(s["arch"]).d_model, s["steps"], s["m"]))
    return cells


def cell_id(spec: dict) -> str:
    """Stable content hash of a cell spec (independent of the sweep name, so
    identical cells dedupe across grids sharing a ledger).

    ``engine`` is EXCLUDED from the hash: the engines are proven
    bitwise-equivalent (PR 1), so a ledger produced on one engine dedupes
    cells for the other instead of silently re-running the whole grid.  The
    engine that actually ran is still recorded in the ledger record's
    ``config``.  Migration note: this changed every id relative to
    pre-PR-4 ledgers — old ledgers no longer dedupe (cells re-run once and
    re-append under their new ids).
    """
    payload = {k: v for k, v in spec.items() if k != "engine"}
    return hashlib.sha1(
        json.dumps(payload, sort_keys=True).encode()
    ).hexdigest()[:12]


def cell_sync_spec(spec: dict) -> str:
    """The ``--sync`` strategy spec one grid cell runs under.  The fragment
    axis is applied through ``SyncStrategy.with_num_fragments`` so
    fragment-wise strategies keep working whatever their option is named."""
    strat = mode_strategy(spec["mode"])
    if spec["streaming_fragments"]:
        strat = strat.with_num_fragments(spec["streaming_fragments"])
    return strat.spec()


def cell_config(sweep: SweepSpec, spec: dict, checkpoint_root: str) -> ExperimentConfig:
    """The ExperimentConfig that runs one grid cell, with its own
    checkpoint directory for step-level resume.  The sync variant goes
    through the strategy registry (``sync=...``), not the legacy flags."""
    ckpt_dir = os.path.join(checkpoint_root, cell_id(spec)) if checkpoint_root else ""
    return ExperimentConfig(
        arch=spec["arch"],
        algorithm="dp" if spec["mode"] == "dp" else "diloco",
        engine=spec["engine"],
        replicas=spec["m"],
        sync_every=spec["h"],
        outer_lr=spec["outer_lr"],
        outer_momentum=spec["outer_momentum"],
        nesterov=spec["nesterov"],
        lr=spec["lr"],
        warmup=max(1, math.ceil(sweep.warmup_frac * spec["steps"])),
        batch_tokens=spec["batch_tokens"],
        seq_len=spec["seq_len"],
        steps=spec["steps"],
        seed=spec["seed"],
        sync=cell_sync_spec(spec),
        eval_batches=sweep.eval_batches,
        eval_seqs=sweep.eval_seqs,
        checkpoint_dir=ckpt_dir,
        checkpoint_every=sweep.checkpoint_every,
        resume=bool(ckpt_dir),
    )


# ---------------------------------------------------------------------------
# Stacking planner + batched runner
# ---------------------------------------------------------------------------


def stack_key(spec: dict) -> tuple:
    """Cells sharing this key are shape-compatible: they trace to identical
    jaxprs and may stack along a leading cell axis.  Everything NOT here
    (lr, outer_lr, outer_momentum, seed) is a traced per-cell array."""
    return (
        spec["arch"], spec["mode"], spec["m"], spec["h"],
        spec["batch_tokens"], spec["seq_len"], spec["steps"],
        spec["nesterov"], spec["streaming_fragments"],
    )


def _has_checkpoint(checkpoint_root: str, cid: str) -> bool:
    d = os.path.join(checkpoint_root, cid)
    if not os.path.isdir(d):
        return False
    return any(
        e.startswith("step_") and not e.endswith(".tmp") for e in os.listdir(d)
    )


def plan_groups(
    cells: list,
    *,
    checkpoint_root: str = "",
    max_group: int = 8,
    min_group: int = 2,
) -> dict:
    """Partition cells into stackable groups: ``{cell_id: group}`` where
    ``group`` is the list of specs that run together (chunked to
    ``max_group`` to bound device memory).  Cells absent from the plan run
    sequentially: singletons, non-superstep engines, and cells with
    existing checkpoints (stacked runs don't checkpoint mid-run, so a
    resumable cell keeps its step-level resume on the sequential path)."""
    buckets: dict = {}
    for spec in cells:
        if spec.get("engine", "superstep") != "superstep":
            continue
        if checkpoint_root and _has_checkpoint(checkpoint_root, cell_id(spec)):
            continue
        buckets.setdefault(stack_key(spec), []).append(spec)
    plan = {}
    for members in buckets.values():
        for i in range(0, len(members), max_group):
            chunk = members[i:i + max_group]
            if len(chunk) >= min_group:
                for s in chunk:
                    plan[cell_id(s)] = chunk
    return plan


def run_cell_batch(
    sweep: SweepSpec, specs: list, checkpoint_root: str = "", *, quiet: bool = True
) -> list:
    """Run K stackable cells as one vmapped executable; return one ledger
    record per cell, in ``specs`` order, matching the sequential
    ``run_experiment`` records field-for-field (eval losses bitwise-equal;
    only ``runtime_s`` — here the batch wall-clock split evenly — differs).
    """
    t0 = time.time()
    configs, trainers, datas = [], [], []
    cfg0 = steps = None
    for spec in specs:
        config = cell_config(sweep, spec, checkpoint_root)
        cfg, trainer, data, steps = make_run(config)
        configs.append(config)
        trainers.append(trainer)
        datas.append(data)
        cfg0 = cfg
    seqs_per_replica = max(
        1, specs[0]["batch_tokens"] // specs[0]["seq_len"] // trainers[0].M)
    engine = CellBatchEngine(trainers, datas, seqs_per_replica)
    states = engine.init_states([spec["seed"] for spec in specs])
    states, mets = engine.run(states, steps)
    losses = np.asarray(mets["loss"])  # (K, steps)

    n_params = _arch_param_count(specs[0]["arch"])
    runtime = time.time() - t0
    cell_states = engine.unstack(states)
    records = []
    for k, (spec, config, trainer, data) in enumerate(
            zip(specs, configs, trainers, datas)):
        eval_seqs = config.eval_seqs or max(1, config.batch_tokens // config.seq_len)
        final_eval, sem = _eval_stats(
            config.eval_batches, data, cell_states[k],
            trainer.jit_eval_step(), eval_seqs)
        history = [
            {"step": i + 1, "loss": float(losses[k, i])} for i in range(steps)
        ]
        # final_train through the same float64 host path as run_experiment
        # (a float32 array mean would drift in the last bits)
        last = [h["loss"] for h in history[-10:]]
        result = ExperimentResult(
            config=config,
            arch=cfg0.name,
            n_params=n_params,
            steps=steps,
            start_step=0,
            tokens=steps * config.batch_tokens,
            final_eval=final_eval,
            final_eval_sem=sem,
            final_train=float(np.mean(last)) if last else float("nan"),
            runtime_s=runtime / len(specs),
            history=history,
            sim=simulate_cell(n_params, steps * config.batch_tokens, config),
        )
        records.append(_json_safe({
            "schema": LEDGER_SCHEMA,
            "cell": cell_id(spec),
            "sweep": sweep.name,
            "spec": spec,
            **result.to_record(),
        }))
    return records


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------


def read_ledger(path: str) -> dict:
    """Completed cells by id.  Append-only JSONL: a crash mid-append can
    leave one truncated trailing line — tolerate and drop it silently (the
    cell will simply re-run, resuming from its checkpoints).  A corrupted
    line anywhere *else* means the file was damaged after the fact (bit
    rot, a concurrent writer, manual editing): skip it too, but with a
    warning, so the damage is visible and at worst re-runs one cell.
    ``"error"`` records (contained cell failures) never mark a cell done."""
    done = {}
    if not os.path.exists(path):
        return done
    with open(path) as f:
        lines = f.read().splitlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                continue  # truncated tail from a killed writer
            warnings.warn(
                f"ledger {path}: skipping corrupted record on line {i + 1} "
                "(mid-file damage — affected cells will re-run)",
                stacklevel=2,
            )
            continue
        if rec.get("schema") == LEDGER_SCHEMA and "cell" in rec:
            if "error" in rec:
                continue  # contained failure: the cell is NOT complete
            done[rec["cell"]] = rec
    return done


def _json_safe(obj):
    """Non-finite floats -> null: the stdlib's default NaN/Infinity tokens
    are invalid JSON and would make the ledger unparseable to strict
    consumers (jq, JSON.parse, ...)."""
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    return obj


def append_record(path: str, rec: dict, *, policy: retry.Policy = retry.DEFAULT) -> None:
    """fsync'd single-line append, retried on transient ``OSError``.

    The fault check runs *before* the file is opened, so an injected (or
    real) transient failure retried by ``retry.call`` can never double-
    append: the write itself happens at most once per successful attempt."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    line = json.dumps(_json_safe(rec), allow_nan=False)

    def attempt():
        faults.io_check("ledger_append")
        with open(path, "a") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())

    retry.call(attempt, policy=policy, retry_on=(OSError,))


# ---------------------------------------------------------------------------
# Driving
# ---------------------------------------------------------------------------


def _attempt_cell(fn, *, retries: int, label: str, quiet: bool):
    """Containment boundary around one cell (or stacked group): run ``fn``
    with bounded backoff retries; return ``(result, None)`` on success or
    ``(None, "ExcType: msg")`` once attempts are exhausted.  The
    ``cell_run`` fault hook fires inside the boundary, so injected
    transient failures exercise exactly this path."""
    pause = retry.delays(retry.Policy(attempts=retries + 1, base_delay=0.1))
    last = None
    for attempt in range(retries + 1):
        if attempt:
            time.sleep(next(pause))
        try:
            faults.io_check("cell_run")
            return fn(), None
        except Exception as e:  # noqa: BLE001 — keep the sweep alive
            last = e
            if not quiet:
                print(
                    f"  {label} attempt {attempt + 1}/{retries + 1} failed: "
                    f"{type(e).__name__}: {e}",
                    flush=True,
                )
    return None, f"{type(last).__name__}: {last}"


def run_sweep(
    sweep: SweepSpec,
    ledger_path: str,
    checkpoint_root: str = "",
    *,
    max_cells: int = 0,
    force: bool = False,
    clean: bool = False,
    quiet: bool = False,
    stack: bool = True,
    stack_max: int = 8,
    contain_errors: bool = True,
    cell_retries: int = 1,
) -> list:
    """Run every grid cell not already in the ledger.

    Returns ``[{"cell", "spec", "skipped", "record"}, ...]`` in grid order.
    ``max_cells`` stops after that many cells actually ran (0 = no limit);
    ``clean`` removes a cell's checkpoint directory once its record is
    durable in the ledger; ``stack=False`` forces every cell onto the
    sequential path (``stack_max`` bounds a stacked group's size).

    Per-cell failures are *contained* (``contain_errors=True``): a cell
    that still fails after ``cell_retries`` backoff retries gets an
    ``"error"`` ledger record (which never marks it complete — a later
    sweep re-runs it) and an entry with ``record=None`` plus the error
    string in the returned list, and the sweep moves on.  A failing
    stacked group falls back to the sequential path member-by-member
    before giving up.  ``contain_errors=False`` restores fail-fast.
    """
    cells = expand_grid(sweep)
    done = {} if force else read_ledger(ledger_path)
    pending = [s for s in cells if cell_id(s) not in done]
    plan = (
        plan_groups(pending, checkpoint_root=checkpoint_root,
                    max_group=stack_max)
        if stack else {}
    )
    out, ran = [], 0
    stacked_recs: dict = {}
    for i, spec in enumerate(cells):
        cid = cell_id(spec)
        if cid in done:
            if not quiet:
                print(f"[{i + 1}/{len(cells)}] {cid} skip (in ledger): {spec}")
            out.append({"cell": cid, "spec": spec, "skipped": True,
                        "record": done[cid]})
            continue
        if cid in stacked_recs:
            # this cell's group already ran (and its record is durable)
            rec = stacked_recs.pop(cid)
            out.append({"cell": cid, "spec": spec, "skipped": False,
                        "record": rec})
            continue
        if max_cells and ran >= max_cells:
            break
        t0 = time.time()
        group = plan.get(cid)
        if group is not None and (not max_cells or ran + len(group) <= max_cells):
            if contain_errors:
                recs, err = _attempt_cell(
                    lambda: run_cell_batch(sweep, group, checkpoint_root,
                                           quiet=quiet),
                    retries=cell_retries,
                    label=f"stacked group x{len(group)} ({cid})", quiet=quiet)
            else:
                recs, err = run_cell_batch(sweep, group, checkpoint_root,
                                           quiet=quiet), None
            if err is None:
                for s2, r2 in zip(group, recs):
                    append_record(ledger_path, r2)
                    stacked_recs[cell_id(s2)] = r2
                ran += len(group)
                rec = stacked_recs.pop(cid)
                if not quiet:
                    print(f"[{i + 1}/{len(cells)}] {cid} "
                          f"eval={rec['final_eval']:.4f} "
                          f"(stacked x{len(group)}, "
                          f"{time.time() - t0:.1f}s total): {spec}", flush=True)
                out.append({"cell": cid, "spec": spec, "skipped": False,
                            "record": rec})
                continue
            # contained group failure: record it against this cell, drop
            # the group from the plan, and fall through to the sequential
            # path — the remaining members run one-by-one at their turn
            append_record(ledger_path, _json_safe({
                "schema": LEDGER_SCHEMA, "cell": cid, "sweep": sweep.name,
                "spec": spec, "error": err, "stacked": len(group)}))
            for s2 in group:
                plan.pop(cell_id(s2), None)
        config = cell_config(sweep, spec, checkpoint_root)
        if contain_errors:
            result, err = _attempt_cell(
                lambda: run_experiment(config, quiet=True),
                retries=cell_retries, label=cid, quiet=quiet)
        else:
            result, err = run_experiment(config, quiet=True), None
        if result is None:
            append_record(ledger_path, _json_safe({
                "schema": LEDGER_SCHEMA, "cell": cid, "sweep": sweep.name,
                "spec": spec, "error": err}))
            ran += 1
            if not quiet:
                print(f"[{i + 1}/{len(cells)}] {cid} FAILED (contained, "
                      f"will re-run next sweep): {err}", flush=True)
            out.append({"cell": cid, "spec": spec, "skipped": False,
                        "record": None, "error": err})
            continue
        rec = _json_safe({
            "schema": LEDGER_SCHEMA,
            "cell": cid,
            "sweep": sweep.name,
            "spec": spec,
            **result.to_record(),
        })
        append_record(ledger_path, rec)
        if clean and config.checkpoint_dir:
            shutil.rmtree(config.checkpoint_dir, ignore_errors=True)
        ran += 1
        if not quiet:
            resumed = f" (resumed@{result.start_step})" if result.start_step else ""
            print(f"[{i + 1}/{len(cells)}] {cid} eval={result.final_eval:.4f} "
                  f"sim={result.sim['wallclock']['total_s']:.2f}s "
                  f"({time.time() - t0:.1f}s){resumed}: {spec}", flush=True)
        out.append({"cell": cid, "spec": spec, "skipped": False, "record": rec})
    return out


def build_argparser():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--grid", default="smoke",
                    help="named SweepSpec from repro.configs.sweeps")
    ap.add_argument("--ledger", default="",
                    help="JSONL ledger path (default results/SWEEP_<grid>.jsonl)")
    ap.add_argument("--checkpoint-root", default="",
                    help="per-cell checkpoint root "
                         "(default results/sweep_<grid>_ckpt; 'none' disables)")
    ap.add_argument("--max-cells", type=int, default=0,
                    help="stop after running this many cells (0 = all)")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells even if already in the ledger")
    ap.add_argument("--clean", action="store_true",
                    help="delete a cell's checkpoints once its record is durable")
    ap.add_argument("--no-stack", dest="stack", action="store_false",
                    help="run every cell sequentially (disable cell batching)")
    ap.add_argument("--stack-max", type=int, default=8,
                    help="max cells stacked into one executable")
    ap.add_argument("--fail-fast", dest="contain", action="store_false",
                    help="abort the sweep on the first cell failure instead "
                         "of recording an error ledger entry and moving on")
    ap.add_argument("--cell-retries", type=int, default=1,
                    help="backoff retries per failing cell before containment")
    ap.add_argument("--list-syncs", action="store_true",
                    help="list the registered sync strategies (valid grid "
                         "modes) and exit")
    ap.add_argument("--no-xla-cache", dest="xla_cache", action="store_false",
                    help="disable the persistent compilation cache "
                         "(results/.xla_cache)")
    return ap


def main(argv=None):
    args = build_argparser().parse_args(argv)
    if args.list_syncs:
        print(sync_lib.describe())
        return
    if args.xla_cache:
        from repro.launch import xla_cache

        xla_cache.enable()
    sweep = get_sweep(args.grid)
    ledger = args.ledger or os.path.join("results", f"SWEEP_{sweep.name}.jsonl")
    ckpt_root = args.checkpoint_root or os.path.join(
        "results", f"sweep_{sweep.name}_ckpt")
    if ckpt_root == "none":
        ckpt_root = ""
    cells = expand_grid(sweep)
    print(f"sweep {sweep.name}: {len(cells)} cells -> {ledger}")
    results = run_sweep(sweep, ledger, ckpt_root,
                        max_cells=args.max_cells, force=args.force,
                        clean=args.clean, stack=args.stack,
                        stack_max=args.stack_max,
                        contain_errors=args.contain,
                        cell_retries=args.cell_retries)
    ran = sum(1 for r in results if not r["skipped"])
    failed = sum(1 for r in results if r.get("error"))
    print(f"done: {ran} ran ({failed} contained failures), "
          f"{sum(1 for r in results if r['skipped'])} skipped, "
          f"{len(cells) - len(results)} remaining")


if __name__ == "__main__":
    main()
