"""End-to-end training driver (CLI).

Runs Data-Parallel or DiLoCo training of any registered architecture on a
(replica, data, model) mesh, with checkpoint/restart, periodic eval on the
held-out stream, straggler simulation, and any registered outer-sync
strategy (``--sync int8``, ``--sync int4``, ``--sync streaming:fragments=4``,
... — ``--list-syncs`` prints the registry; ``repro.core.sync`` is the
extension point).

Two execution engines (``--engine``):

* ``superstep`` (default) — one compiled, donated executable per outer
  round: ``lax.scan`` over the H inner steps with on-device batch
  generation, the outer sync fused in, and ONE host sync per round
  (``repro.core.superstep``).  Eval/checkpoint cadences are rounded to
  outer-round boundaries.
* ``per-step`` — the classic one-dispatch-per-inner-step loop (kept for
  debugging and as the perf baseline; see ``benchmarks/bench_engine.py``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tiny-t1 --algorithm diloco \
      --replicas 4 --sync-every 30 --steps 200 --batch-tokens 8192
  PYTHONPATH=src python -m repro.launch.train --arch chinchilla-35m --algorithm dp
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time

import jax
import numpy as np

from repro import sharding
from repro.checkpoint import Checkpointer
from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core import compute_util, elastic, faults, wallclock
from repro.core import sync as sync_lib
from repro.core.diloco import make_trainer
from repro.core.superstep import SuperstepEngine
from repro.data import SyntheticLM, TokenFileSource
from repro.launch.mesh import make_mesh
from repro.models import build_model


@dataclasses.dataclass(frozen=True)
class ExperimentConfig:
    """One fully-specified training experiment (a sweep cell).

    Field names mirror the CLI argparse dests, so an instance can drive
    ``make_run``/``train_loop`` anywhere an ``args`` namespace is expected;
    ``ExperimentConfig.from_args`` converts a parsed namespace.
    """

    arch: str = "tiny-t1"
    algorithm: str = "diloco"        # dp | diloco
    engine: str = "superstep"        # superstep | per-step
    replicas: int = 1                # M
    sync_every: int = 30             # H
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    nesterov: bool = True
    lr: float = 3e-3
    warmup: int = 100
    batch_tokens: int = 8192         # B
    seq_len: int = 256
    steps: int = 0                   # 0 -> Chinchilla D=20N (x overtrain)
    overtrain: float = 1.0
    seed: int = 0
    mesh: str = "1,1,1"
    sync: str = ""                   # strategy spec "name[:k=v,...]"; see --list-syncs
    compression: str = "none"        # none | int8 (legacy spelling of --sync)
    streaming_fragments: int = 0
    tokens_file: str = ""
    eval_every: int = 0
    eval_batches: int = 4
    eval_seqs: int = 0               # final-eval batch size; 0 -> B / seq_len
    #                                  (M-independent so cells are comparable)
    checkpoint_dir: str = ""
    checkpoint_every: int = 0
    resume: bool = False
    log_every: int = 0
    straggler_rate: float = 0.0
    faults: str = ""                 # deterministic fault schedule spec
    #                                  (repro.core.faults.parse grammar)
    metrics_out: str = ""

    @classmethod
    def from_args(cls, args) -> "ExperimentConfig":
        return cls(**{
            f.name: getattr(args, f.name)
            for f in dataclasses.fields(cls) if hasattr(args, f.name)
        })

    def replace(self, **kw) -> "ExperimentConfig":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ExperimentResult:
    """What one experiment produced: measured losses plus the idealized
    wall-clock / compute-utilization simulation for the same (N, M, H, B)
    cell (paper Appendix A / §5.1)."""

    config: ExperimentConfig
    arch: str
    n_params: int
    steps: int
    start_step: int                  # >0 when the cell resumed mid-run
    tokens: int
    final_eval: float
    final_eval_sem: float
    final_train: float
    runtime_s: float
    history: list
    sim: dict

    def to_record(self) -> dict:
        """Flat JSON-serializable form (the sweep-ledger payload)."""
        return {
            "config": self.config.to_dict(),
            "arch": self.arch,
            "n_params": self.n_params,
            "steps": self.steps,
            "start_step": self.start_step,
            "tokens": self.tokens,
            "final_eval": self.final_eval,
            "final_eval_sem": self.final_eval_sem,
            "final_train": self.final_train,
            "runtime_s": self.runtime_s,
            "sim": self.sim,
        }


def config_strategy(config: ExperimentConfig) -> "sync_lib.SyncStrategy":
    """The resolved sync strategy for one experiment config — ``sync`` spec
    first, then the legacy algorithm/compression/streaming fields (no
    deprecation warning here: this is the read-only accounting path)."""
    if config.sync:
        return sync_lib.parse_spec(config.sync)
    if config.algorithm == "dp":
        return sync_lib.get("dp")
    if config.compression != "none":
        return sync_lib.get(config.compression)
    if config.streaming_fragments > 0:
        return sync_lib.get("streaming", fragments=config.streaming_fragments)
    return sync_lib.get("full")


def simulate_cell(n_params: int, tokens: int, config: ExperimentConfig) -> dict:
    """Idealized wall-clock + compute-utilization for one cell.

    ``wallclock.train_time`` gives the Appendix-A end-to-end seconds; the
    Table-6 CU model adds the utilization at the default cross-DC bandwidth.
    Outer-sync comm is billed through the cell's ``SyncStrategy``
    (``outer_payload_bytes`` per event x ``sync_events_per_round``): int8
    halves the outer payload, int4 quarters it, streaming splits it across
    P per-round events.
    """
    strat = config_strategy(config)
    algorithm = "diloco" if strat.uses_outer_opt else "dp"
    m = config.replicas if algorithm == "diloco" else 1
    h = config.sync_every if algorithm == "diloco" else 1
    straggler_factor = 1.0
    fault_spec = getattr(config, "faults", "")
    if fault_spec and m > 1:
        # bill the schedule's stragglers: each round runs at the pace of
        # its slowest surviving replica
        rounds = max(1, math.ceil(tokens / config.batch_tokens / h))
        straggler_factor = faults.parse(fault_spec).mean_slowdown(rounds, m)
    wall = wallclock.train_time(
        n_params, tokens, config.batch_tokens,
        algorithm=algorithm, m_replicas=m, sync_every=h,
        outer_payload_bytes=strat.outer_payload_bytes(n_params),
        outer_syncs_per_round=strat.sync_events_per_round,
        straggler_factor=straggler_factor,
    )
    r = wallclock.num_chips(config.batch_tokens)
    step_time = wallclock.compute_time(n_params, config.batch_tokens, r)
    ratio = strat.compression_ratio
    if algorithm == "diloco" and m > 1:
        # outer sync: all-reduce across the M replica groups every H steps
        cu = compute_util.compute_utilization(
            n_params / ratio, step_time, wallclock.MEDIUM.bandwidth,
            sync_every=h, r_nodes=m,
        )
    else:
        # every-step all-reduce over all R chips (DP; DiLoCo M=1 outer is
        # local); r_nodes=1 means no collective at all -> CU = 1.0, matching
        # wallclock's comm_s == 0 for the same cell
        cu = compute_util.compute_utilization(
            n_params, step_time, wallclock.MEDIUM.bandwidth,
            sync_every=1, r_nodes=r,
        )
    return {
        "wallclock": wall,
        "step_time_s": step_time,
        "cu_at_medium_bw": cu,
        "outer_payload_ratio": ratio,
    }


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-t1")
    ap.add_argument("--algorithm", choices=["dp", "diloco"], default="diloco")
    ap.add_argument("--engine", choices=["superstep", "per-step"], default="superstep",
                    help="superstep: one compiled executable per outer round; "
                         "per-step: one dispatch per inner step")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--sync-every", type=int, default=30)
    ap.add_argument("--outer-lr", type=float, default=0.7)
    ap.add_argument("--outer-momentum", type=float, default=0.9)
    ap.add_argument("--no-nesterov", dest="nesterov", action="store_false",
                    help="plain SGD(+momentum) outer updates")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--batch-tokens", type=int, default=8192)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--steps", type=int, default=0, help="0 = Chinchilla D=20N")
    ap.add_argument("--overtrain", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="1,1,1", help="replica,data,model")
    ap.add_argument("--sync", default="",
                    help="outer-sync strategy spec 'name[:key=value,...]' "
                         "(e.g. int8, int4, streaming:fragments=4); "
                         "see --list-syncs.  Overrides the legacy "
                         "--compression/--streaming-fragments flags")
    ap.add_argument("--list-syncs", action="store_true",
                    help="list the registered sync strategies and exit")
    ap.add_argument("--compression", choices=["none", "int8"], default="none",
                    help="(deprecated: use --sync int8)")
    ap.add_argument("--streaming-fragments", type=int, default=0,
                    help="(deprecated: use --sync streaming:fragments=P)")
    ap.add_argument("--tokens-file", default="",
                    help="binary token file -> TokenFileSource (prefetched "
                         "host batches instead of on-device synthetic data)")
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--straggler-rate", type=float, default=0.0,
                    help="probability a replica misses an outer sync (fault-tolerance demo)")
    ap.add_argument("--faults", default="",
                    help="deterministic fault schedule, e.g. "
                         "'crash:replica=1,at=2,rejoin=4;straggle:replica=0,"
                         "start=1,stop=3,factor=2.5;io:op=ledger_append,"
                         "fails=2;seed=7' (repro.core.faults grammar): "
                         "crashed replicas are masked out of the outer "
                         "average and re-seeded from the global params on "
                         "rejoin; exactly reproducible from the spec")
    ap.add_argument("--metrics-out", default="")
    ap.add_argument("--no-xla-cache", dest="xla_cache", action="store_false",
                    help="disable the persistent compilation cache "
                         "(results/.xla_cache)")
    return ap


def make_run(args):
    cfg = get_config(args.arch).replace(max_seq_len=args.seq_len)
    model = build_model(cfg)
    n_params = model.param_count()
    steps = args.steps or max(int(20 * n_params * args.overtrain / args.batch_tokens), 1)
    tcfg = TrainConfig(
        global_batch_tokens=args.batch_tokens, seq_len=args.seq_len, steps=steps,
        seed=args.seed,
    )
    sync_spec = getattr(args, "sync", "")
    if sync_spec and args.algorithm == "dp" and \
            sync_lib.parse_spec(sync_spec).uses_outer_opt:
        raise ValueError(
            f"--algorithm dp conflicts with --sync {sync_spec!r} (an "
            "outer-optimizer strategy); drop --algorithm or use --sync dp"
        )
    dcfg = DiLoCoConfig(
        num_replicas=args.replicas if args.algorithm == "diloco" else 1,
        sync_every=args.sync_every,
        outer_lr=args.outer_lr,
        outer_momentum=args.outer_momentum,
        nesterov=getattr(args, "nesterov", True),
        # --sync wins over the legacy spellings; passing both non-default
        # is rejected by DiLoCoConfig itself
        data_parallel=args.algorithm == "dp" and not sync_spec,
        compression=args.compression,
        streaming_fragments=args.streaming_fragments,
        sync=sync_spec,
    )
    ocfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=args.warmup)
    trainer = make_trainer(model, dcfg, ocfg, tcfg)
    if getattr(args, "tokens_file", ""):
        data = TokenFileSource(args.tokens_file, seq_len=args.seq_len)
    else:
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len, seed=args.seed + 1)
    return cfg, trainer, data, steps


def _straggler_weights(args, rng, m):
    mask = rng.random(m) >= args.straggler_rate
    if not mask.any():
        mask[rng.integers(m)] = True
    return elastic.participation_weights(mask)


def _eval_stats(n_batches, data, state, eval_step, eval_seqs):
    evals = [
        float(eval_step(state, data.batch(10_000 + i, 0, 1, eval_seqs, eval=True)))
        for i in range(n_batches)
    ]
    return float(np.mean(evals)), float(np.std(evals) / np.sqrt(max(len(evals), 1)))


def _eval_record(args, data, state, eval_step, seqs_per_replica):
    mean, _ = _eval_stats(args.eval_batches, data, state, eval_step, seqs_per_replica)
    return mean


def train_loop(args, trainer, data, steps, *, mesh=None, rules=None, quiet=False):
    m = trainer.M
    seqs_per_replica = max(1, args.batch_tokens // args.seq_len // m)
    ckpt = Checkpointer(args.checkpoint_dir, trainer=trainer) if args.checkpoint_dir else None

    state, start = None, 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        # template-free restore: exact dtypes/values from the manifest-v2
        # checkpoint, device_put sharded onto the current mesh, and elastic
        # M -> trainer.M resize if --replicas changed since the save
        state, start = ckpt.restore()
        if not quiet:
            print(f"resumed from step {start} (M={trainer.M})")
    if state is None:
        state = trainer.init_state(jax.random.PRNGKey(args.seed))

    if args.straggler_rate > 0 and trainer.sync.num_fragments > 0 and not quiet:
        print("warning: --straggler-rate has no effect with fragment-wise "
              "sync strategies (fragment syncs always average all replicas)")

    schedule = None
    if getattr(args, "faults", ""):
        schedule = faults.parse(args.faults)
        if schedule.has_replica_events() and not (
                m > 1 and trainer.sync.pins_round_boundary
                and trainer.sync.uses_outer_opt) and not quiet:
            print("warning: --faults crash/straggle events need M > 1 and a "
                  "round-pinned outer-sync strategy; ignoring them")

    if getattr(args, "engine", "superstep") == "superstep":
        loop = _superstep_loop
    else:
        loop = _per_step_loop
    state, history = loop(
        args, trainer, data, steps, state, start, ckpt,
        seqs_per_replica=seqs_per_replica, quiet=quiet, schedule=schedule,
    )
    if ckpt:
        ckpt.wait()
        # save at the state's own step (== steps after a full run; a resume
        # at/past the end must not publish a manifest claiming a step the
        # state isn't at), unless the periodic cadence already wrote it
        cur = int(np.asarray(state["step"]))
        if ckpt.latest_step() != cur:
            ckpt.save(state, cur)
        ckpt.close()
    return state, history


def _superstep_loop(args, trainer, data, steps, state, start, ckpt, *,
                    seqs_per_replica, quiet, schedule=None):
    """One compiled round per dispatch; host syncs once per round.

    Eval and checkpoint cadences fire at the end of the round in which they
    come due (the engine never breaks a round open mid-scan).
    """
    engine = SuperstepEngine(trainer, data, seqs_per_replica)
    try:
        return _superstep_rounds(
            args, trainer, data, steps, state, start, ckpt, engine,
            seqs_per_replica=seqs_per_replica, quiet=quiet, schedule=schedule,
        )
    finally:
        engine.close()  # drop speculative readahead on exit or error


def _superstep_rounds(args, trainer, data, steps, state, start, ckpt, engine, *,
                      seqs_per_replica, quiet, schedule=None):
    eval_step = trainer.jit_eval_step()
    rng = np.random.default_rng(args.seed + 99)
    m = trainer.M
    H = engine.chunk
    # Fault-schedule masks are round-indexed off the ABSOLUTE step counter,
    # so a resumed run replays the exact mask/reseed sequence of an
    # uninterrupted one (the chaos smoke pins this bitwise).
    use_masks = (schedule is not None and m > 1
                 and trainer.sync.pins_round_boundary)
    history = []
    t0 = time.time()
    step = start
    while step < steps:
        end, nxt = engine.round_bounds(step, steps)
        if use_masks and step % H == 0:
            rejoin = schedule.rejoin_mask(step // H, m)
            if rejoin.any():
                # replicas back from the dead: global params, cold inner opt
                state = elastic.reseed_replicas(trainer, state, rejoin)
        weights = None
        if use_masks and end % H == 0:
            # ALWAYS an explicit weights operand while a schedule is active
            # (even all-alive rounds): a None <-> array flip would change
            # the jit input structure and recompile; a constant operand
            # shape keeps one executable across every mask sequence
            weights = elastic.participation_weights(
                schedule.participation_mask((end - 1) // H, m))
        elif (args.straggler_rate > 0 and m > 1
                and trainer.sync.pins_round_boundary and end % H == 0):
            weights = _straggler_weights(args, rng, m)
        state, mets = engine.run_round(state, step, end - step, weights=weights,
                                       next_length=nxt)
        losses = np.atleast_1d(np.asarray(mets["loss"]))
        for i in range(end - step):
            history.append({"step": step + i + 1, "loss": float(losses[i])})
        window = range(step + 1, end + 1)
        eval_due = args.eval_every and any(s % args.eval_every == 0 for s in window)
        if eval_due or end == steps:
            history[-1]["eval_nll"] = _eval_record(
                args, data, state, eval_step, seqs_per_replica)
        log_due = args.log_every and any(s % args.log_every == 0 for s in window)
        if not quiet and (log_due or end == steps):
            e = (f" eval={history[-1]['eval_nll']:.4f}"
                 if "eval_nll" in history[-1] else "")
            print(f"step {end}/{steps} loss={history[-1]['loss']:.4f}{e} "
                  f"({(time.time()-t0)/(end-start):.3f}s/step)", flush=True)
        if ckpt and args.checkpoint_every and any(
                s % args.checkpoint_every == 0 for s in window):
            ckpt.save_async(state, end)
        step = end
    return state, history


def _per_step_loop(args, trainer, data, steps, state, start, ckpt, *,
                   seqs_per_replica, quiet, schedule=None):
    m = trainer.M
    strat = trainer.sync
    inner = trainer.jit_inner_step()
    outer = trainer.jit_outer_sync()
    eval_step = trainer.jit_eval_step()
    rng = np.random.default_rng(args.seed + 99)
    H = trainer.dcfg.sync_every
    # same absolute-round mask/reseed placement as the superstep engine —
    # the engine-equivalence tests hold bitwise under any mask sequence
    use_masks = (schedule is not None and m > 1
                 and strat.pins_round_boundary and strat.uses_outer_opt)
    history = []
    t0 = time.time()
    for step in range(start, steps):
        if use_masks and step % H == 0:
            rejoin = schedule.rejoin_mask(step // H, m)
            if rejoin.any():
                state = elastic.reseed_replicas(trainer, state, rejoin)
        batch = data.global_batch(step, m, seqs_per_replica)
        state, metrics = inner(state, batch)
        if strat.uses_outer_opt:
            if strat.num_fragments > 0:
                for p in strat.fragments_due(step + 1, trainer.dcfg.sync_every):
                    state = strat.jitted_fragment(trainer, p)(state)
            elif (step + 1) % trainer.dcfg.sync_every == 0:
                weights = None
                if use_masks:
                    weights = elastic.participation_weights(
                        schedule.participation_mask(step // H, m))
                elif args.straggler_rate > 0 and m > 1:
                    weights = _straggler_weights(args, rng, m)
                state = outer(state, weights)
        rec = {"step": step + 1, "loss": float(metrics["loss"])}
        if args.eval_every and (step + 1) % args.eval_every == 0 or step == steps - 1:
            rec["eval_nll"] = _eval_record(
                args, data, state, eval_step, seqs_per_replica)
        history.append(rec)
        if not quiet and args.log_every and (step + 1) % args.log_every == 0:
            e = f" eval={rec.get('eval_nll', float('nan')):.4f}" if "eval_nll" in rec else ""
            print(f"step {step+1}/{steps} loss={rec['loss']:.4f}{e} "
                  f"({(time.time()-t0)/(step-start+1):.2f}s/step)", flush=True)
        if ckpt and args.checkpoint_every and (step + 1) % args.checkpoint_every == 0:
            ckpt.save_async(state, step + 1)
    return state, history


def run_experiment(config: ExperimentConfig, *, quiet: bool = True) -> ExperimentResult:
    """Run one fully-specified experiment end to end; return its result.

    This is the reusable core of the CLI (and the unit the sweep driver
    schedules): build trainer + data, run ``train_loop`` on the configured
    engine (with checkpoint/resume when ``config.checkpoint_dir`` is set),
    evaluate the final state on a fixed-size held-out batch (independent of
    M, so losses are comparable across cells), and attach the Appendix-A
    wall-clock / Table-6 CU simulation for the same cell.
    """
    cfg, trainer, data, steps = make_run(config)
    n_params = trainer.model.param_count()
    eval_seqs = config.eval_seqs or max(1, config.batch_tokens // config.seq_len)

    t0 = time.time()
    r, d, mdl = (int(x) for x in config.mesh.split(","))
    if r * d * mdl > 1:
        mesh = make_mesh(r, d, mdl)
        with sharding.set_mesh(mesh), sharding.use_rules(dict(sharding.DEFAULT_RULES)):
            state, history = train_loop(config, trainer, data, steps, mesh=mesh,
                                        quiet=quiet)
            final_eval, sem = _eval_stats(config.eval_batches, data, state,
                                          trainer.jit_eval_step(), eval_seqs)
    else:
        state, history = train_loop(config, trainer, data, steps, quiet=quiet)
        final_eval, sem = _eval_stats(config.eval_batches, data, state,
                                      trainer.jit_eval_step(), eval_seqs)
    runtime_s = time.time() - t0

    final_step = int(np.asarray(state["step"]))
    losses = [h["loss"] for h in history[-10:]]
    return ExperimentResult(
        config=config,
        arch=cfg.name,
        n_params=n_params,
        steps=steps,
        start_step=final_step - len(history),
        tokens=steps * config.batch_tokens,
        final_eval=final_eval,
        final_eval_sem=sem,
        final_train=float(np.mean(losses)) if losses else float("nan"),
        runtime_s=runtime_s,
        history=history,
        sim=simulate_cell(n_params, steps * config.batch_tokens, config),
    )


def main(argv=None):
    args = build_argparser().parse_args(argv)
    if args.list_syncs:
        print(sync_lib.describe())
        return
    if getattr(args, "xla_cache", True):
        from repro.launch import xla_cache

        xla_cache.enable()
    config = ExperimentConfig.from_args(args)
    cfg, trainer, _, steps = make_run(config)  # banner from the same budget rule
    print(f"arch={cfg.name} N={trainer.model.param_count()/1e6:.2f}M params "
          f"algo={config.algorithm} M={trainer.M} H={config.sync_every} "
          f"steps={steps} engine={config.engine}")
    result = run_experiment(config, quiet=False)
    history = result.history
    if history:
        final = history[-1]
        print(f"final: loss={final['loss']:.4f} eval_nll={result.final_eval:.4f} "
              f"sim_total={result.sim['wallclock']['total_s']:.1f}s")
    else:
        print(f"nothing to do: resumed at step {result.start_step} "
              f">= steps ({steps})")
    if config.metrics_out:
        with open(config.metrics_out, "w") as f:
            json.dump(history, f)


if __name__ == "__main__":
    main()
