"""End-to-end training driver (CLI).

Runs Data-Parallel or DiLoCo training of any registered architecture on a
(replica, data, model) mesh, with checkpoint/restart, periodic eval on the
held-out stream, straggler simulation, and optional int8 outer compression /
streaming fragment sync.

Two execution engines (``--engine``):

* ``superstep`` (default) — one compiled, donated executable per outer
  round: ``lax.scan`` over the H inner steps with on-device batch
  generation, the outer sync fused in, and ONE host sync per round
  (``repro.core.superstep``).  Eval/checkpoint cadences are rounded to
  outer-round boundaries.
* ``per-step`` — the classic one-dispatch-per-inner-step loop (kept for
  debugging and as the perf baseline; see ``benchmarks/bench_engine.py``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tiny-t1 --algorithm diloco \
      --replicas 4 --sync-every 30 --steps 200 --batch-tokens 8192
  PYTHONPATH=src python -m repro.launch.train --arch chinchilla-35m --algorithm dp
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import sharding
from repro.checkpoint import Checkpointer
from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core import elastic, streaming
from repro.core.diloco import make_trainer
from repro.core.superstep import SuperstepEngine
from repro.data import SyntheticLM, TokenFileSource
from repro.launch.mesh import make_mesh
from repro.models import build_model


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-t1")
    ap.add_argument("--algorithm", choices=["dp", "diloco"], default="diloco")
    ap.add_argument("--engine", choices=["superstep", "per-step"], default="superstep",
                    help="superstep: one compiled executable per outer round; "
                         "per-step: one dispatch per inner step")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--sync-every", type=int, default=30)
    ap.add_argument("--outer-lr", type=float, default=0.7)
    ap.add_argument("--outer-momentum", type=float, default=0.9)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--batch-tokens", type=int, default=8192)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--steps", type=int, default=0, help="0 = Chinchilla D=20N")
    ap.add_argument("--overtrain", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="1,1,1", help="replica,data,model")
    ap.add_argument("--compression", choices=["none", "int8"], default="none")
    ap.add_argument("--streaming-fragments", type=int, default=0)
    ap.add_argument("--tokens-file", default="",
                    help="binary token file -> TokenFileSource (prefetched "
                         "host batches instead of on-device synthetic data)")
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--straggler-rate", type=float, default=0.0,
                    help="probability a replica misses an outer sync (fault-tolerance demo)")
    ap.add_argument("--metrics-out", default="")
    return ap


def make_run(args):
    cfg = get_config(args.arch).replace(max_seq_len=args.seq_len)
    model = build_model(cfg)
    n_params = model.param_count()
    steps = args.steps or max(int(20 * n_params * args.overtrain / args.batch_tokens), 1)
    tcfg = TrainConfig(
        global_batch_tokens=args.batch_tokens, seq_len=args.seq_len, steps=steps,
        seed=args.seed,
    )
    dcfg = DiLoCoConfig(
        num_replicas=args.replicas if args.algorithm == "diloco" else 1,
        sync_every=args.sync_every,
        outer_lr=args.outer_lr,
        outer_momentum=args.outer_momentum,
        data_parallel=args.algorithm == "dp",
        compression=args.compression,
        streaming_fragments=args.streaming_fragments,
    )
    ocfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=args.warmup)
    trainer = make_trainer(model, dcfg, ocfg, tcfg)
    if getattr(args, "tokens_file", ""):
        data = TokenFileSource(args.tokens_file, seq_len=args.seq_len)
    else:
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len, seed=args.seed + 1)
    return cfg, trainer, data, steps


def _straggler_weights(args, rng, m):
    mask = rng.random(m) >= args.straggler_rate
    if not mask.any():
        mask[rng.integers(m)] = True
    return elastic.participation_weights(mask)


def _eval_record(args, data, state, eval_step, seqs_per_replica):
    evals = [
        float(eval_step(state, data.batch(10_000 + i, 0, 1, seqs_per_replica, eval=True)))
        for i in range(args.eval_batches)
    ]
    return float(np.mean(evals))


def train_loop(args, trainer, data, steps, *, mesh=None, rules=None, quiet=False):
    m = trainer.M
    seqs_per_replica = max(1, args.batch_tokens // args.seq_len // m)
    ckpt = Checkpointer(args.checkpoint_dir, trainer=trainer) if args.checkpoint_dir else None

    state, start = None, 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        # template-free restore: exact dtypes/values from the manifest-v2
        # checkpoint, device_put sharded onto the current mesh, and elastic
        # M -> trainer.M resize if --replicas changed since the save
        state, start = ckpt.restore()
        if not quiet:
            print(f"resumed from step {start} (M={trainer.M})")
    if state is None:
        state = trainer.init_state(jax.random.PRNGKey(args.seed))

    if args.straggler_rate > 0 and trainer.dcfg.streaming_fragments > 0 and not quiet:
        print("warning: --straggler-rate has no effect with streaming "
              "fragments (fragment syncs always average all replicas)")

    if getattr(args, "engine", "superstep") == "superstep":
        loop = _superstep_loop
    else:
        loop = _per_step_loop
    state, history = loop(
        args, trainer, data, steps, state, start, ckpt,
        seqs_per_replica=seqs_per_replica, quiet=quiet,
    )
    if ckpt:
        ckpt.wait()
        # save at the state's own step (== steps after a full run; a resume
        # at/past the end must not publish a manifest claiming a step the
        # state isn't at), unless the periodic cadence already wrote it
        cur = int(np.asarray(state["step"]))
        if ckpt.latest_step() != cur:
            ckpt.save(state, cur)
        ckpt.close()
    return state, history


def _superstep_loop(args, trainer, data, steps, state, start, ckpt, *,
                    seqs_per_replica, quiet):
    """One compiled round per dispatch; host syncs once per round.

    Eval and checkpoint cadences fire at the end of the round in which they
    come due (the engine never breaks a round open mid-scan).
    """
    engine = SuperstepEngine(trainer, data, seqs_per_replica)
    try:
        return _superstep_rounds(
            args, trainer, data, steps, state, start, ckpt, engine,
            seqs_per_replica=seqs_per_replica, quiet=quiet,
        )
    finally:
        engine.close()  # drop speculative readahead on exit or error


def _superstep_rounds(args, trainer, data, steps, state, start, ckpt, engine, *,
                      seqs_per_replica, quiet):
    eval_step = jax.jit(trainer.eval_step)
    rng = np.random.default_rng(args.seed + 99)
    m = trainer.M
    H = engine.chunk
    history = []
    t0 = time.time()
    step = start
    while step < steps:
        end, nxt = engine.round_bounds(step, steps)
        weights = None
        if (args.straggler_rate > 0 and m > 1 and not trainer.dcfg.data_parallel
                and trainer.dcfg.streaming_fragments == 0 and end % H == 0):
            weights = _straggler_weights(args, rng, m)
        state, mets = engine.run_round(state, step, end - step, weights=weights,
                                       next_length=nxt)
        losses = np.atleast_1d(np.asarray(mets["loss"]))
        for i in range(end - step):
            history.append({"step": step + i + 1, "loss": float(losses[i])})
        window = range(step + 1, end + 1)
        eval_due = args.eval_every and any(s % args.eval_every == 0 for s in window)
        if eval_due or end == steps:
            history[-1]["eval_nll"] = _eval_record(
                args, data, state, eval_step, seqs_per_replica)
        log_due = args.log_every and any(s % args.log_every == 0 for s in window)
        if not quiet and (log_due or end == steps):
            e = (f" eval={history[-1]['eval_nll']:.4f}"
                 if "eval_nll" in history[-1] else "")
            print(f"step {end}/{steps} loss={history[-1]['loss']:.4f}{e} "
                  f"({(time.time()-t0)/(end-start):.3f}s/step)", flush=True)
        if ckpt and args.checkpoint_every and any(
                s % args.checkpoint_every == 0 for s in window):
            ckpt.save_async(state, end)
        step = end
    return state, history


def _per_step_loop(args, trainer, data, steps, state, start, ckpt, *,
                   seqs_per_replica, quiet):
    m = trainer.M
    inner = trainer.jit_inner_step()
    outer = trainer.jit_outer_sync()
    frag = (streaming.FragmentSync(trainer)
            if trainer.dcfg.streaming_fragments > 0 and not trainer.dcfg.data_parallel
            else None)
    eval_step = jax.jit(trainer.eval_step)
    rng = np.random.default_rng(args.seed + 99)
    history = []
    t0 = time.time()
    for step in range(start, steps):
        batch = data.global_batch(step, m, seqs_per_replica)
        state, metrics = inner(state, batch)
        if not trainer.dcfg.data_parallel:
            if frag is not None:
                for p in streaming.fragments_due(
                    step + 1, trainer.dcfg.streaming_fragments, trainer.dcfg.sync_every
                ):
                    state = frag.jitted(p)(state)
            elif (step + 1) % trainer.dcfg.sync_every == 0:
                weights = None
                if args.straggler_rate > 0 and m > 1:
                    weights = _straggler_weights(args, rng, m)
                state = outer(state, weights)
        rec = {"step": step + 1, "loss": float(metrics["loss"])}
        if args.eval_every and (step + 1) % args.eval_every == 0 or step == steps - 1:
            rec["eval_nll"] = _eval_record(
                args, data, state, eval_step, seqs_per_replica)
        history.append(rec)
        if not quiet and args.log_every and (step + 1) % args.log_every == 0:
            e = f" eval={rec.get('eval_nll', float('nan')):.4f}" if "eval_nll" in rec else ""
            print(f"step {step+1}/{steps} loss={rec['loss']:.4f}{e} "
                  f"({(time.time()-t0)/(step-start+1):.2f}s/step)", flush=True)
        if ckpt and args.checkpoint_every and (step + 1) % args.checkpoint_every == 0:
            ckpt.save_async(state, step + 1)
    return state, history


def main():
    args = build_argparser().parse_args()
    cfg, trainer, data, steps = make_run(args)
    r, d, mdl = (int(x) for x in args.mesh.split(","))
    print(f"arch={cfg.name} N={build_model(cfg).param_count()/1e6:.2f}M params "
          f"algo={args.algorithm} M={trainer.M} H={args.sync_every} steps={steps} "
          f"engine={args.engine}")
    if r * d * mdl > 1:
        mesh = make_mesh(r, d, mdl)
        with sharding.set_mesh(mesh), sharding.use_rules(dict(sharding.DEFAULT_RULES)):
            state, history = train_loop(args, trainer, data, steps, mesh=mesh)
    else:
        state, history = train_loop(args, trainer, data, steps)
    if history:
        final = history[-1]
        floor = data.entropy_floor() if hasattr(data, "entropy_floor") else float("nan")
        print(f"final: loss={final['loss']:.4f} eval_nll={final.get('eval_nll', float('nan')):.4f} "
              f"(source entropy floor ~{floor:.4f})")
    else:
        print(f"nothing to do: resumed at step {int(np.asarray(state['step']))} "
              f">= steps ({steps})")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f)


if __name__ == "__main__":
    main()
