"""Three-term roofline analysis from compiled dry-run artifacts.

Terms (per device; ``cost_analysis()`` on a partitioned module reports
per-device numbers, verified empirically):

    compute    = HLO_FLOPs      / peak_FLOP/s        (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes      / HBM_bw             (819 GB/s)
    collective = wire_bytes     / ICI_bw             (~50 GB/s/link)

``wire_bytes`` is NOT in cost_analysis: we parse the partitioned HLO text
and sum per-op traffic with bandwidth-optimal ring models:

    all-reduce       2 * size * (n-1)/n      (reduce-scatter + all-gather)
    all-gather       size_out * (n-1)/n
    reduce-scatter   size_in  * (n-1)/n
    all-to-all       size * (n-1)/n
    collective-permute  size

where ``size`` is the per-device operand size in the partitioned module and
``n`` the replica-group size parsed from the op.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

# ---- TPU v5e hardware constants (assignment) -------------------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (collective term denominator)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shape_bytes(txt: str, f32_bytes: int = 4) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(txt):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * (f32_bytes if dtype == "f32" else _DTYPE_BYTES[dtype])
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_V2_RE.search(line)  # e.g. replica_groups=[32,16] -> 16 per group
    if m:
        return int(m.group(2))
    return 2


def collective_traffic(hlo_text: str, f32_as_bf16: bool = False) -> Dict[str, float]:
    """Per-device wire bytes by collective kind, from partitioned HLO text.

    ``f32_as_bf16``: XLA:CPU upcasts bf16 einsums to f32 *before* SPMD
    partitioning, so activation collectives in a bf16-lowered module print
    as f32 — on TPU they are bf16.  Setting this counts f32 payloads at
    2 bytes (used for bf16-dtype dry-run modules; the raw count is also
    recorded).  Validated by dtype audit of the deepseek-67b probe HLO
    (EXPERIMENTS.md §Perf iteration 0).
    """
    f32_bytes = 2 if f32_as_bf16 else 4
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        op = None
        for kind in _COLLECTIVES:
            # match op invocation, not metadata mentions
            if re.search(rf"(?:^|\)\s|\}}\s|\]\s){kind}(?:-start|-done)?\(", rhs) or rhs.lstrip().startswith(kind):
                op = kind
                break
        if op is None:
            continue
        if f"{op}-done" in rhs:
            continue  # bytes counted on the -start op
        # output shape(s) sit between '=' and the op name on the RHS
        head = rhs.split(op)[0]
        size = _shape_bytes(head, f32_bytes)
        n = _group_size(rhs)
        if op == "all-reduce":
            traffic = 2 * size * (n - 1) / max(n, 1)
        elif op in ("all-gather", "all-to-all"):
            traffic = size * (n - 1) / max(n, 1)
        elif op == "reduce-scatter":
            traffic = size * (n - 1)  # input = n * output shards
        else:  # collective-permute
            traffic = size
        out[op] += traffic
        out["count"] += 1
    out["total_bytes"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    collective_bytes_per_dev: float
    chips: int
    model_flops_total: float      # 6*N*D (train) / 2*N*D (serve), N=active params

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Idealized no-overlap upper bound and roofline lower bound is the
        max term; we report the max (perfect overlap of the other two)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_total = self.flops_per_dev * self.chips
        return self.model_flops_total / hlo_total if hlo_total else float("nan")

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.step_time_s * PEAK_FLOPS * self.chips
        return self.model_flops_total / denom if denom else float("nan")

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "collective_bytes_per_dev": self.collective_bytes_per_dev,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def analyze(compiled, *, chips: int, model_flops_total: float, hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    traffic = collective_traffic(txt)
    return Roofline(
        flops_per_dev=flops,
        bytes_per_dev=byts,
        collective_bytes_per_dev=traffic["total_bytes"],
        chips=chips,
        model_flops_total=model_flops_total,
    )


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        }
    except Exception as e:  # CPU backend may not implement everything
        return {"error": str(e)}
