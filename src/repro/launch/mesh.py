"""Production meshes + per-(arch, workload) sharding rules.

The DiLoCo replica axis is bound to the ``pod`` mesh axis (DESIGN.md §3):
inner-step collectives stay inside a pod; the outer Δ all-reduce is the only
cross-pod collective.  ``make_production_mesh`` is a FUNCTION so importing
this module never touches jax device state.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.sharding import DEFAULT_RULES


def _make_mesh(shape, axes):
    # axis_types landed after jax 0.4.x; Auto is the default there anyway
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(replica: int = 1, data: int = 1, model: int = 1):
    """Small explicit (replica, data, model) mesh for tests/examples."""
    return _make_mesh((replica, data, model), ("replica", "data", "model"))


# ---------------------------------------------------------------------------
# Sharding-rule selection
# ---------------------------------------------------------------------------

# Per-arch overrides: dims that do not divide the 16-way model axis fall back
# to replicated (or to an alternative axis). Kept here — model configs stay
# hardware-agnostic.
ARCH_RULE_OVERRIDES = {
    "granite-moe-3b-a800m": {"heads": None, "experts": None, "expert_ff": "model",
                             "vocab": None},   # 24 heads / 40 experts / 49155 vocab !% 16
    "gemma-2b": {"heads": None},               # 8 heads; big dims (ff, vocab) carry TP
    "smollm-360m": {"heads": None},            # 15 heads
    "mamba2-130m": {"ssm_heads": None, "vocab": None},  # 24 ssm heads, 50280 vocab
    "seamless-m4t-medium": {"vocab": None},    # 256206 !% 16
}


def rules_for(
    arch: str,
    kind: str,                 # train | prefill | decode
    *,
    multi_pod: bool = False,
    global_batch: Optional[int] = None,
    data_axis: int = 16,
    overrides: Optional[dict] = None,
) -> dict:
    """Logical->mesh binding for one dry-run cell / training run."""
    rules = dict(DEFAULT_RULES)
    rules["replica"] = "pod" if multi_pod else None

    if kind == "decode":
        # flash-decode style: the KV-cache sequence axis carries the model
        # axis (q is a single token — gathering it is ~free; softmax partials
        # all-reduce over "model"). Weights keep their TP sharding.
        rules["kv_seq"] = "model"
        rules["groups"] = None       # MoE decode groups are tiny
        if global_batch is not None and global_batch < data_axis:
            # long-context single-stream decode: nothing to shard on batch;
            # spread the cache/sequence over BOTH axes
            rules["batch"] = None
            rules["kv_seq"] = ("data", "model")
    if kind == "prefill":
        rules["groups"] = "data"

    rules.update(ARCH_RULE_OVERRIDES.get(arch, {}))
    if overrides:
        rules.update(overrides)
    return rules


def auto_validate_rules(model, rules: dict, axis_sizes: dict):
    """Drop logical->mesh bindings whose tensor dims don't divide the axis.

    Safety net behind ARCH_RULE_OVERRIDES: scans every parameter PSpec of
    the model and replicates (None) any logical axis that would shard a
    non-divisible dimension (GSPMD would pad; we prefer explicit layouts).
    Returns (validated_rules, {logical: (dim, mesh_axis, size)} dropped).
    """
    import jax

    from repro.models.layers import PSpec

    dropped = {}
    for leaf in jax.tree.leaves(model.specs(), is_leaf=lambda x: isinstance(x, PSpec)):
        for dim, ax in zip(leaf.shape, leaf.axes):
            if ax is None or rules.get(ax) is None:
                continue
            mesh_ax = rules[ax]
            for a in (mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)):
                size = axis_sizes.get(a, 1)
                if size > 1 and dim % size:
                    dropped[ax] = (dim, a, size)
    out = dict(rules)
    for ax in dropped:
        out[ax] = None
    return out, dropped
