"""Analytic cost model (napkin math, per DESIGN.md §Perf methodology).

Used two ways:
 1. cross-check of the HLO-derived numbers for unrolled dry-run cells;
 2. primary flops/bytes source for the few cells whose chunk/layer loops
    stay as ``lax.scan`` (XLA cost_analysis counts scan bodies once —
    a known artifact), marked "analytic" in EXPERIMENTS.md.

Conventions: matmul flops = 2*m*n*k; causal attention halves the quadratic
term; backward = 2x forward; full remat adds ~1x forward recompute.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeSpec


def _attn_fwd_flops(cfg: ModelConfig, tq: int, tkv: int, causal: bool) -> float:
    f = 4.0 * tq * tkv * cfg.n_heads * cfg.head_dim  # QK^T + PV
    if causal and tq == tkv:
        f /= 2
    return f


def _ssd_fwd_flops(cfg: ModelConfig, t: int) -> float:
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    q = min(cfg.ssm_chunk, t)
    nc = max(t // q, 1)
    per_chunk = (
        2.0 * q * q * h * n      # C_i B_j^T
        + 2.0 * q * q * h * p    # L-weighted @ X
        + 2.0 * q * h * n * p    # chunk state (B^T X)
        + 2.0 * q * h * n * p    # inter-chunk (C S)
    )
    return nc * per_chunk


def _embed_rows(cfg: ModelConfig) -> int:
    return cfg.vocab_size * cfg.d_model * (2 if not cfg.tie_embeddings else 1)


def fwd_flops_total(cfg: ModelConfig, batch: int, seq: int, *, decode_kv: int = 0) -> float:
    """Forward flops for `batch` sequences of `seq` tokens (decode: seq=1 and
    attention runs against a decode_kv-long cache)."""
    n_active = cfg.active_param_count()
    matmul_params = n_active - _embed_rows(cfg) + cfg.vocab_size * cfg.d_model  # +unembed matmul
    tokens = batch * seq
    total = 2.0 * matmul_params * tokens
    for i in range(cfg.n_layers):
        if cfg.layer_kind(i) == "attn":
            tkv = decode_kv if decode_kv else seq
            total += batch * _attn_fwd_flops(cfg, seq, tkv, causal=True)
        else:
            if decode_kv:
                total += batch * 8.0 * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
            else:
                total += batch * _ssd_fwd_flops(cfg, seq)
    for _ in range(cfg.encoder_layers):
        tf = cfg.n_frontend_tokens
        total += batch * _attn_fwd_flops(cfg, tf, tf, causal=False)
        # cross-attention of each decoded token over encoder output
        total += batch * 4.0 * seq * tf * cfg.n_heads * cfg.head_dim / max(cfg.encoder_layers, 1) * cfg.n_layers
    return total


def analytic_costs(cfg: ModelConfig, shape: ShapeSpec, chips: int) -> dict:
    """{flops_per_dev, bytes_per_dev} under perfect sharding."""
    p_bytes = cfg.param_count() * 2  # bf16
    b = shape.global_batch
    d = cfg.d_model
    if shape.kind == "train":
        fwd = fwd_flops_total(cfg, b, shape.seq_len)
        flops = 4.0 * fwd  # fwd + 2x bwd + 1x remat recompute
        act = b * shape.seq_len * d * 2.0 * cfg.n_layers * 4  # boundaries, fwd w + bwd r + recompute
        opt = cfg.param_count() * (8 + 8 + 8)   # m,v fp32 rw + grads fp32 rw
        byts = p_bytes * 3 + opt + act
    elif shape.kind == "prefill":
        flops = fwd_flops_total(cfg, b, shape.seq_len)
        kv_write = 2.0 * sum(
            1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn"
        ) * b * shape.seq_len * cfg.n_kv_heads * cfg.head_dim * 2
        act = b * shape.seq_len * d * 2.0 * cfg.n_layers * 2
        byts = p_bytes + act + kv_write
    else:  # decode
        flops = fwd_flops_total(cfg, b, 1, decode_kv=shape.seq_len)
        kv_read = 2.0 * sum(
            1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn"
        ) * b * shape.seq_len * cfg.n_kv_heads * cfg.head_dim * 2
        byts = p_bytes + kv_read
    return {
        "flops_per_dev": flops / chips,
        "bytes_per_dev": byts / chips,
        "flops_total": flops,
    }
