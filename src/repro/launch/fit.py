"""Fit the paper's scaling laws from a sweep ledger (§6, Tables 7-13).

Consumes the JSONL ledger written by ``repro.launch.sweep`` and emits one
versioned JSON artifact with:

* independent power laws  L(N) = A·N^α  per (mode, M)        (Tables 7-9)
* the joint power law     L(N,M) = A·N^α·M^β                 (Table 10)
* quadratic-in-log2(B) optimal-batch interpolation, and the growth of the
  optimal batch with M                                        (§6.1, Finding 3)
* the four parametric L(N,M) forms (Huber-on-log, multi-restart, largest-N
  holdout when there is enough data)                          (§6.5, Table 13)
* headline artifacts: DiLoCo-vs-DP loss at the fixed token budget, and the
  simulated wall-clock / compute-utilization overlay per cell (Appendix A)

  PYTHONPATH=src python -m repro.launch.fit --ledger results/SWEEP_smoke.jsonl
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core import scaling_laws as sl
from repro.launch.sweep import _json_safe, read_ledger

FIT_SCHEMA = 1


# ---------------------------------------------------------------------------
# Ledger -> tidy cells
# ---------------------------------------------------------------------------


def _cells(records) -> list:
    out = []
    for rec in records:
        s = rec["spec"]
        out.append({
            "cell": rec["cell"],
            "mode": s["mode"],
            "arch": s["arch"],
            "n": float(rec["n_params"]),
            "m": int(s["m"]),
            "h": int(s["h"]),
            "b": int(s["batch_tokens"]),
            "tokens": int(rec["tokens"]),
            "eval": float(rec["final_eval"]),
            "sim": rec.get("sim", {}),
        })
    return out


def _tuned(cells, keys=("mode", "m", "n")) -> dict:
    """Min eval loss per group — the paper fits at tuned hyperparameters,
    so within a group the best (H, B) cell represents the scale."""
    best = {}
    for c in cells:
        k = tuple(c[kk] for kk in keys)
        if k not in best or c["eval"] < best[k]["eval"]:
            best[k] = c
    return best


# ---------------------------------------------------------------------------
# Fits
# ---------------------------------------------------------------------------


def _power_laws(cells) -> dict:
    out = {}
    tuned = _tuned(cells)
    groups = {}
    for (mode, m, n), c in tuned.items():
        groups.setdefault((mode, m), []).append((n, c["eval"]))
    for (mode, m), pts in sorted(groups.items()):
        if len({n for n, _ in pts}) < 2:
            continue
        pts.sort()
        n = [p[0] for p in pts]
        y = [p[1] for p in pts]
        A, alpha = sl.fit_power_law(n, y)
        out[f"{mode}_m{m}"] = {
            "A": A, "alpha": alpha,
            "n_points": len(pts),
            "residual": sl.residual(y, sl.predict_power_law(A, alpha, n)),
        }
    return out


def _diloco_points(cells):
    tuned = _tuned([c for c in cells if c["mode"] == "diloco"])
    pts = sorted(tuned.values(), key=lambda c: (c["n"], c["m"]))
    n = np.array([c["n"] for c in pts])
    m = np.array([c["m"] for c in pts])
    y = np.array([c["eval"] for c in pts])
    return n, m, y


def _joint(cells) -> dict:
    n, m, y = _diloco_points(cells)
    if len(n) < 3 or len(set(n)) < 2 or len(set(m)) < 2:
        return {"skipped": f"need >=2 N and >=2 M (have {len(set(n))} N, {len(set(m))} M)"}
    A, alpha, beta = sl.fit_joint_power_law(n, m, y)
    return {
        "A": A, "alpha": alpha, "beta": beta,
        "n_points": int(len(n)),
        "residual": sl.residual(y, sl.predict_joint(A, alpha, beta, n, m)),
    }


def _optimal_batch(cells) -> dict:
    """Quadratic-in-log2(B) optimum per (mode, M, N); then the growth of
    the optimum with M (the paper's Finding 3: bigger M -> bigger B_opt)."""
    groups = {}
    for c in cells:
        groups.setdefault((c["mode"], c["m"], c["n"]), []).append(c)
    optima = {}
    for (mode, m, n), cs in sorted(groups.items()):
        byb = _tuned(cs, keys=("b",))
        if len(byb) < 3:
            continue  # a quadratic needs >= 3 batch sizes
        bs = sorted(k[0] for k in byb)
        losses = [byb[(b,)]["eval"] for b in bs]
        optima[f"{mode}_m{m}_n{n:.3g}"] = {
            "mode": mode, "m": m, "n": n,
            "b_opt": sl.quadratic_log2_optimum(bs, losses),
            "b_grid": bs,
        }
    out = {"per_cell": optima}
    # B_opt(M) power law over DiLoCo optima at fixed N
    byn = {}
    for o in optima.values():
        if o["mode"] == "diloco":
            byn.setdefault(o["n"], []).append((o["m"], o["b_opt"]))
    growth = {}
    for n, pts in sorted(byn.items()):
        if len(pts) < 2:
            continue
        pts.sort()
        A, gamma = sl.fit_power_law([p[0] for p in pts], [p[1] for p in pts])
        growth[f"n{n:.3g}"] = {"A": A, "gamma": gamma, "m_grid": [p[0] for p in pts]}
    out["growth_with_m"] = growth
    return out


def _parametric(cells, restarts: int, seed: int = 0) -> dict:
    n, m, y = _diloco_points(cells)
    out = {}
    if len(n) < 3 or len(set(n)) < 2:
        return {"skipped": f"need >=3 DiLoCo points over >=2 N (have {len(n)})"}
    holdout = None
    if len(n) >= 6 and len(set(n)) >= 3:
        holdout = n >= sorted(set(n))[-1]  # paper §6.5: hold out the largest scale
    n_train = int(len(n) - (holdout.sum() if holdout is not None else 0))
    for form, (_, k) in sl.PARAMETRIC_FORMS.items():
        if n_train <= k:
            out[form] = {"skipped": f"{n_train} training points cannot constrain {k} params"}
            continue
        params, train_obj, sel = sl.fit_parametric(
            form, n, m, y, restarts=restarts, seed=seed, holdout_mask=holdout)
        pred = sl.parametric_predict(form, params, n, m)
        out[form] = {
            "params": [float(p) for p in params],
            "train_obj": train_obj,
            "holdout_residual": sel if holdout is not None else None,
            "residual": sl.residual(y, pred),
        }
    return out


def _headline(cells) -> dict:
    """The paper's headline artifacts from the raw cells."""
    tuned = _tuned(cells)
    # DiLoCo vs DP eval loss at the (fixed) token budget, per scale
    vs = []
    ns = sorted({c["n"] for c in cells})
    for n in ns:
        dp = tuned.get(("dp", 1, n))
        if dp is None:
            continue
        row = {"n": n, "arch": dp["arch"], "tokens": dp["tokens"], "dp": dp["eval"]}
        for (mode, m, nn), c in sorted(tuned.items()):
            if nn == n and mode != "dp":
                row[f"{mode}_m{m}"] = c["eval"]
                row[f"{mode}_m{m}_minus_dp"] = c["eval"] - dp["eval"]
        vs.append(row)
    # simulated wall-clock / CU overlay (Appendix A): loss vs idealized time
    overlay = [
        {
            "cell": c["cell"], "mode": c["mode"], "m": c["m"], "h": c["h"],
            "n": c["n"], "b": c["b"], "eval": c["eval"],
            "sim_total_s": c["sim"].get("wallclock", {}).get("total_s"),
            "sim_comm_s": c["sim"].get("wallclock", {}).get("comm_s"),
            "cu": c["sim"].get("cu_at_medium_bw"),
        }
        for c in sorted(cells, key=lambda c: (c["n"], c["mode"], c["m"], c["h"], c["b"]))
    ]
    return {"diloco_vs_dp": vs, "wallclock_overlay": overlay}


def fit_ledger(records, *, restarts: int = 32, seed: int = 0) -> dict:
    """All fits from a list of ledger records (see module docstring)."""
    cells = _cells(records)
    return {
        "schema": FIT_SCHEMA,
        "n_cells": len(cells),
        "power_laws": _power_laws(cells),
        "joint": _joint(cells),
        "optimal_batch": _optimal_batch(cells),
        "parametric": _parametric(cells, restarts, seed),
        "headline": _headline(cells),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--ledger", required=True, help="SWEEP_*.jsonl ledger path")
    ap.add_argument("--out", default="",
                    help="output JSON (default: ledger path with SWEEP_ -> "
                         "FITS_ and .jsonl -> .json)")
    ap.add_argument("--restarts", type=int, default=32,
                    help="multi-restart count for the parametric fits")
    args = ap.parse_args()
    records = list(read_ledger(args.ledger).values())
    if not records:
        raise SystemExit(f"no ledger records in {args.ledger}")
    fits = fit_ledger(records, restarts=args.restarts)
    fits["ledger"] = args.ledger
    out = args.out or args.ledger.replace("SWEEP_", "FITS_").replace(".jsonl", ".json")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(_json_safe(fits), f, indent=1, allow_nan=False)
    print(f"fit {fits['n_cells']} cells -> {out}")
    laws = fits["power_laws"]
    for k in sorted(laws):
        v = laws[k]
        print(f"  L(N)|{k}: A={v['A']:.3f} alpha={v['alpha']:.4f} "
              f"res={v['residual']:.4f} ({v['n_points']} pts)")
    j = fits["joint"]
    if "alpha" in j:
        print(f"  L(N,M): A={j['A']:.3f} alpha={j['alpha']:.4f} beta={j['beta']:.4f} "
              f"res={j['residual']:.4f}")


if __name__ == "__main__":
    main()
