"""Opt-in JAX persistent compilation cache for the launch entry points.

A sweep re-run (or a CI job) pays full XLA compilation for every distinct
cell shape even though nothing changed since the last run.  JAX's
persistent compilation cache keys compiled executables by a hash of the
HLO + compile options and stores them on disk, so a warm cache skips
backend compilation entirely — with hyperparameters traced through the
state (``repro.core.diloco``), a re-run of a whole grid typically compiles
nothing.

``enable()`` points the cache at ``results/.xla_cache`` (override with the
``REPRO_XLA_CACHE_DIR`` env var; set it to ``off`` / ``0`` / ``none`` to
disable).  Thresholds are zeroed because sweep cells are tiny models whose
compiles fall under JAX's default 1s / 0-byte gates.  Safe to call more
than once; returns the cache dir, or None when disabled/unsupported.

The cache is content-addressed and append-only: deleting the directory is
always safe (the next run just recompiles), and it can be relocated by
pointing the env var elsewhere — see README "Batched sweeps & the
compilation cache".
"""
from __future__ import annotations

import os
from typing import Optional

DEFAULT_DIR = os.path.join("results", ".xla_cache")
_OFF = {"off", "0", "none", "false"}


def enable(path: str = "") -> Optional[str]:
    """Enable the persistent compilation cache; return its dir (or None)."""
    env = os.environ.get("REPRO_XLA_CACHE_DIR", "")
    if env.lower() in _OFF:
        return None
    cache_dir = os.path.abspath(path or env or DEFAULT_DIR)
    try:
        import jax

        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # sweep cells are tiny: without zeroed gates nothing would qualify
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:
        return None  # older jax without the knobs: run uncached
    return cache_dir
