"""Named experiment-grid presets for the scaling-law sweep driver.

A ``SweepSpec`` is the cross product of the paper's grid axes — model size
N (via arch names), replicas M, sync cadence H, global batch B, and the
outer-sync mode — plus the per-cell training recipe.  ``repro.launch.sweep``
expands a spec into concrete cells, runs each on the superstep engine, and
records them in a JSONL ledger that ``repro.launch.fit`` turns into the
paper's fitted scaling laws.

Modes are registered sync-strategy names (``repro.core.sync``; any strategy
a user registers is a valid grid mode as-is), plus the historical
``diloco`` spelling of the full-precision strategy:

* ``dp``        — Data-Parallel baseline (M forced to 1, no outer step)
* ``diloco``    — paper Algorithm 1, full-precision outer sync (``full``)
* ``int8``      — int8-compressed outer deltas with error feedback
* ``int4``      — int4 block-quantized outer deltas with error feedback
* ``streaming`` — Streaming-DiLoCo fragment sync (P fragments per round)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


def default_lr(d_model: int) -> float:
    """Fixed per-width inner-lr rule (the paper sweeps lr per scale; a CPU
    box cannot — 1/sqrt(width) is the standard mu-P-flavored default, same
    rule as benchmarks/common.py)."""
    return 3e-3 * (64 / d_model) ** 0.5


@dataclass(frozen=True)
class SweepSpec:
    """One named sweep: grid axes (tuples) x shared per-cell recipe."""

    name: str
    # --- grid axes ------------------------------------------------------
    archs: tuple = ("tiny-t0", "tiny-t1")
    modes: tuple = ("dp", "diloco")
    replicas: tuple = (1, 2)
    sync_every: tuple = (5,)
    batch_tokens: tuple = (2048,)
    # hyperparameter axes (paper Tables 7-13 sweep lr per scale).  Empty
    # tuple = collapse to the scalar recipe value below.  Cells that differ
    # ONLY along these axes (and seeds) are shape-compatible, so the sweep
    # driver stacks them into one vmapped executable
    # (repro.core.cellbatch) instead of running them sequentially.
    lrs: tuple = ()                  # () -> (lr or default_lr(d_model),)
    outer_lrs: tuple = ()            # () -> (outer_lr,)
    seeds: tuple = ()                # () -> (seed,)
    # --- per-cell recipe ------------------------------------------------
    seq_len: int = 128
    steps: int = 0                   # 0 -> budget_mult * N / B (constant rule)
    budget_mult: float = 5.0         # reduced-Chinchilla D = 5N on CPU
    min_steps: int = 10
    lr: float = 0.0                  # 0 -> default_lr(d_model)
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    nesterov: bool = True
    warmup_frac: float = 0.1         # warmup = ceil(frac * steps)
    seed: int = 0
    eval_batches: int = 4
    eval_seqs: int = 16              # fixed M-independent eval batch
    streaming_fragments: int = 2     # P when mode == "streaming"
    checkpoint_every: int = 0        # 0 = final checkpoint only
    engine: str = "superstep"

    def replace(self, **kw) -> "SweepSpec":
        return dataclasses.replace(self, **kw)


SWEEPS = {
    # CI smoke: reduced (N x M) grid, a handful of steps per cell — proves
    # the ledger / per-cell-resume / fit loop end to end in minutes.
    "smoke": SweepSpec(
        name="smoke",
        archs=("tiny-t0", "tiny-t1"),
        modes=("dp", "diloco"),
        replicas=(1, 2),
        sync_every=(4,),
        batch_tokens=(1024,),
        seq_len=64,
        steps=8,
        lr=3e-3,
        warmup_frac=0.25,
        eval_batches=2,
        eval_seqs=8,
        checkpoint_every=4,
    ),
    # Stackable smoke: one (arch, M, H, B) shape swept over lr x seed per
    # mode — each mode's 6 cells form one cell-batched group, so this grid
    # exercises (and benchmarks) the vmap-stacked sweep path end to end.
    # The int4 mode keeps the registry-only strategy path on every CI run
    # (make bench-sweep-smoke -> results/BENCH_sweep_smoke.json).
    "smoke-stack": SweepSpec(
        name="smoke-stack",
        archs=("tiny-t0",),
        modes=("diloco", "int4"),
        replicas=(2,),
        sync_every=(4,),
        batch_tokens=(1024,),
        lrs=(3e-3, 2e-3, 1e-3),
        seeds=(0, 1),
        seq_len=64,
        steps=8,
        warmup_frac=0.25,
        eval_batches=2,
        eval_seqs=8,
    ),
    # CPU-feasible ladder: the benchmark grid as a ledger-producing sweep
    # (tiny family, all five sync modes, the paper's M / H / B axes reduced).
    "ladder": SweepSpec(
        name="ladder",
        archs=("tiny-t0", "tiny-t1", "tiny-t2"),
        modes=("dp", "diloco", "int8", "int4", "streaming"),
        replicas=(1, 2, 4),
        sync_every=(5, 15),
        batch_tokens=(2048, 8192),
        seq_len=128,
        budget_mult=5.0,
        checkpoint_every=50,
    ),
    # The paper's actual grid (Tables 4-13): Chinchilla family, M in
    # {1,2,4,8}, H=30, B swept around the per-scale optimum, D=20N.
    # Definition of done for the full reproduction; needs accelerators.
    "paper": SweepSpec(
        name="paper",
        archs=("chinchilla-35m", "chinchilla-90m", "chinchilla-180m",
               "chinchilla-330m", "chinchilla-550m", "chinchilla-1.3b",
               "chinchilla-2.4b"),
        modes=("dp", "diloco"),
        replicas=(1, 2, 4, 8),
        sync_every=(30,),
        batch_tokens=(2 ** 16, 2 ** 17, 2 ** 18, 2 ** 19),
        seq_len=2048,
        budget_mult=20.0,
        warmup_frac=0.05,
        checkpoint_every=500,
    ),
}


def get_sweep(name: str) -> SweepSpec:
    try:
        return SWEEPS[name]
    except KeyError:
        raise KeyError(f"unknown sweep {name!r}; known: {sorted(SWEEPS)}") from None
