"""granite-moe-3b-a800m [hf:ibm-granite]: 40 experts top-8, small experts."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,               # per-expert width
    vocab_size=49_155,
    act="silu",
    glu=True,
    moe=True,
    n_experts=40,
    n_shared_experts=0,
    top_k=8,
    moe_d_ff=512,
    moe_layer_freq=1,
    tie_embeddings=True,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, moe_d_ff=32, n_experts=4, top_k=2, vocab_size=256,
    moe_group_size=64, remat=False,
)
