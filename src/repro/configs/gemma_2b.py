"""gemma-2b [arXiv:2403.08295]: GeGLU, head_dim 256, MQA (kv=1), vocab 256k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,            # MQA
    head_dim=256,
    d_ff=16_384,
    vocab_size=256_000,
    act="gelu",
    glu=True,                # GeGLU
    tie_embeddings=True,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=512, remat=False,
)
