"""qwen3-8b [hf:Qwen/Qwen3-8B]: dense, QK-norm, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12_288,
    vocab_size=151_936,
    act="silu",
    glu=True,
    qk_norm=True,
    tie_embeddings=False,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False,
)
