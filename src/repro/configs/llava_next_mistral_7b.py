"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf]: Mistral-7B
backbone; anyres vision tiling is a STUB per the assignment — input_specs()
provides precomputed patch embeddings (base 576 + 4 tiles x 576 = 2880)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    act="silu",
    glu=True,
    frontend="vision_stub",
    n_frontend_tokens=2880,   # anyres: 576 base + 2x2 grid of 576
    tie_embeddings=False,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, n_frontend_tokens=16, remat=False,
)
