"""smollm-360m [hf:HuggingFaceTB/SmolLM]: llama-arch small model."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49_152,
    act="silu",
    glu=True,
    tie_embeddings=True,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, head_dim=20,
    d_ff=128, vocab_size=256, remat=False,
)
