"""jamba-1.5-large-398b [arXiv:2403.19887]: Mamba+attention 1:7 interleave,
MoE 16e top-2 every other layer.  Our SSM blocks are Mamba-2 SSD (TPU
adaptation; see DESIGN.md §4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    act="silu",
    glu=True,
    moe=True,
    n_experts=16,
    top_k=2,
    moe_d_ff=24_576,
    moe_layer_freq=2,        # MoE every other layer
    dense_d_ff=24_576,
    attn_layer_period=8,     # 1 attention layer per 8 (1:7 attn:mamba)
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=False,
    max_seq_len=524_288,
    layer_group=8,           # scan over 9 groups of 8 layers
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, moe_d_ff=128, dense_d_ff=128, n_experts=4, top_k=2,
    vocab_size=256, attn_layer_period=2, layer_group=2,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=16, moe_group_size=64, remat=False,
)
