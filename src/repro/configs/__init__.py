"""Architecture registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

The 10 assigned architectures plus the paper's own Chinchilla family
(``chinchilla-35m`` ... ``chinchilla-10b``) are selectable by name
(``--arch <id>`` in the launchers).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    DiLoCoConfig,
    MeshConfig,
    ModelConfig,
    OptimizerConfig,
    SHAPE_GRID,
    ShapeSpec,
    TrainConfig,
    shape_by_name,
)
from repro.configs.sweeps import SWEEPS, SweepSpec, get_sweep  # noqa: F401

_ASSIGNED = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "gemma-2b": "gemma_2b",
    "qwen3-8b": "qwen3_8b",
    "smollm-360m": "smollm_360m",
    "deepseek-67b": "deepseek_67b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mamba2-130m": "mamba2_130m",
}

ASSIGNED_ARCHS = tuple(_ASSIGNED)

# archs with sub-quadratic sequence mixing -> run the long_500k cell
SUBQUADRATIC = ("jamba-1.5-large-398b", "mamba2-130m")


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{_ASSIGNED[arch]}")


def get_config(arch: str) -> ModelConfig:
    if arch in _ASSIGNED:
        return _module(arch).CONFIG
    if arch.startswith("chinchilla-"):
        from repro.models.chinchilla import chinchilla_config

        return chinchilla_config(arch.removeprefix("chinchilla-"))
    if arch.startswith("tiny-"):
        from repro.models.chinchilla import tiny_ladder

        return tiny_ladder()[arch.removeprefix("tiny-")]
    raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ASSIGNED)} + chinchilla-*")


def get_smoke_config(arch: str) -> ModelConfig:
    if arch in _ASSIGNED:
        return _module(arch).SMOKE
    return get_config(arch).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, remat=False,
    )


def cells(arch: str):
    """The dry-run shape cells for an arch, applying the assignment's skips."""
    out = []
    for s in SHAPE_GRID:
        if s.name == "long_500k" and arch not in SUBQUADRATIC:
            continue  # pure full-attention archs skip long-context decode
        out.append(s)
    return out
