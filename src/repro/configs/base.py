"""Config dataclasses for the repro framework.

Every architecture in the zoo is described by a single ``ModelConfig``;
training/serving runs add a ``TrainConfig`` / ``ServeConfig``; the DiLoCo
algorithm itself is configured by ``DiLoCoConfig`` (the paper's Table 2
notation: M replicas, sync cadence H, inner lr gamma, outer lr eta).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  Defaults describe a dense llama-style LM."""

    name: str = "unnamed"
    family: str = "dense"  # dense | moe | hybrid | vlm | audio | ssm

    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 512

    # --- nonlinearity / norms -------------------------------------------
    act: str = "silu"          # silu (SwiGLU when glu) | gelu (GeGLU when glu)
    glu: bool = True
    qk_norm: bool = False
    norm_eps: float = 1e-6

    # --- positional -----------------------------------------------------
    rope_theta: float = 10_000.0

    # --- MoE -------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0          # per-expert FFN width
    moe_layer_freq: int = 1    # MoE every k-th layer (jamba: 2); 1 = every layer
    first_dense: int = 0       # leading dense layers (deepseek-moe: 1)
    dense_d_ff: int = 0        # FFN width of the dense layers of a MoE model
    capacity_factor: float = 1.25
    moe_group_size: int = 1024  # dispatch group size (tokens)
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2

    # --- hybrid / SSM -----------------------------------------------------
    attn_layer_period: int = 0  # 0 = every layer is attention; k = 1 attn per k layers
    ssm_state: int = 0          # mamba2 N (d_state); 0 = no ssm layers
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_n_groups: int = 1
    unroll_ssm: bool = False     # dry-run: unroll the SSD chunk loop

    # --- encoder-decoder ---------------------------------------------------
    encoder_layers: int = 0     # >0 -> enc-dec model (decoder has cross-attn)

    # --- modality frontend stub ---------------------------------------------
    frontend: str = "none"      # none | vision_stub | audio_stub
    n_frontend_tokens: int = 0  # patch/frame embeddings prepended to the sequence

    # --- misc ----------------------------------------------------------------
    tie_embeddings: bool = True
    max_seq_len: int = 8192
    z_loss: float = 1e-4
    dtype: str = "float32"       # param/compute dtype ("bfloat16" on TPU)
    remat: bool = True           # activation checkpointing across the layer scan
    remat_policy: str = "nothing"  # nothing | save_comm (keep AR'd activations;
    #                                recompute skips the 2 fwd TP all-reduces)
    scan_layers: bool = True     # scan over (grouped) layers to keep HLO small
    layer_group: int = 1         # layers fused into one scan body (hybrid: period)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return max(1, self.d_inner // self.ssm_head_dim)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def layer_kind(self, i: int) -> str:
        """Mixer kind of layer i: 'attn' or 'ssm'."""
        if self.ssm_state == 0:
            return "attn"
        if self.attn_layer_period == 0:
            return "ssm"
        # jamba convention: one attention layer per period, at period offset
        # `period // 2` (attn in the middle of each block of `period` layers).
        return "attn" if i % self.attn_layer_period == self.attn_layer_period // 2 else "ssm"

    def mlp_kind(self, i: int) -> str:
        """FFN kind of layer i: 'dense' or 'moe'."""
        if not self.moe:
            return "dense"
        if i < self.first_dense:
            return "dense"
        return "moe" if (i - self.first_dense) % self.moe_layer_freq == 0 else "dense"

    def param_count(self) -> int:
        """Analytic parameter count (used for D=20N budgets and rooflines)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # token embedding
        if not self.tie_embeddings:
            total += v * d
        n_mats = 3 if self.glu else 2

        def ffn_params(width: int) -> int:
            return n_mats * d * width

        def attn_params() -> int:
            q = d * self.n_heads * self.head_dim
            kv = 2 * d * self.n_kv_heads * self.head_dim
            o = self.n_heads * self.head_dim * d
            return q + kv + o

        def ssm_params() -> int:
            di, ns, g = self.d_inner, self.ssm_state, self.ssm_n_groups
            h = self.ssm_heads
            in_proj = d * (2 * di + 2 * g * ns + h)
            conv = (di + 2 * g * ns) * self.ssm_conv
            out = di * d
            extras = 2 * h + di  # A_log, D, norm
            return in_proj + conv + out + extras

        def moe_params() -> int:
            p = self.n_experts * n_mats * d * self.moe_d_ff
            p += self.n_shared_experts * n_mats * d * self.moe_d_ff
            p += d * self.n_experts  # router
            return p

        dec_layers = self.n_layers
        for i in range(dec_layers):
            total += attn_params() if self.layer_kind(i) == "attn" else ssm_params()
            if self.mlp_kind(i) == "moe":
                total += moe_params()
            else:
                total += ffn_params(self.dense_d_ff or self.d_ff)
            total += 2 * d  # two norms
        for _ in range(self.encoder_layers):
            total += attn_params() + ffn_params(self.d_ff) + 2 * d
        if self.encoder_layers:
            total += dec_layers * (attn_params() + d)  # cross attention + its norm
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k + shared experts count)."""
        if not self.moe:
            return self.param_count()
        n_mats = 3 if self.glu else 2
        d = self.d_model
        inactive_per_moe_layer = (self.n_experts - self.top_k) * n_mats * d * self.moe_d_ff
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.mlp_kind(i) == "moe")
        return self.param_count() - n_moe_layers * inactive_per_moe_layer


@dataclass(frozen=True)
class DiLoCoConfig:
    """Paper Table 2: algorithm-specific knobs."""

    num_replicas: int = 1            # M
    sync_every: int = 30             # H
    outer_lr: float = 0.7            # eta
    outer_momentum: float = 0.9      # Nesterov momentum
    nesterov: bool = True
    data_parallel: bool = False      # True = pure Data-Parallel (no outer opt)
    # --- outer-sync strategy -------------------------------------------
    # Registered strategy spec "name[:key=value,...]" (repro.core.sync):
    # "dp" | "full" | "int8" | "int4" | "streaming:fragments=P" | any
    # user-registered strategy.  Empty = resolve from the legacy flags
    # below (data_parallel / compression / streaming_fragments — the
    # deprecation shim keeps old configs, ledgers, and checkpoints valid).
    sync: str = ""
    # --- legacy flags (deprecated spellings of the above) ---------------
    compression: str = "none"        # none | int8  (outer-Δ all-reduce compression)
    streaming_fragments: int = 0     # >0 -> Streaming DiLoCo with P fragments
    error_feedback: bool = True      # residual accumulation for compressed sync

    def __post_init__(self):
        if self.sync and (self.data_parallel or self.compression != "none"
                          or self.streaming_fragments > 0):
            raise ValueError(
                f"sync={self.sync!r} is exclusive with the legacy "
                "data_parallel/compression/streaming_fragments flags; the "
                "strategy spec already says how replicas synchronize"
            )
        if self.streaming_fragments < 0:
            raise ValueError(f"streaming_fragments must be >= 0, got {self.streaming_fragments}")
        if self.streaming_fragments > 0 and self.compression != "none":
            # fragment syncs bypass the compressed outer path entirely, so
            # accepting both would silently drop compression (and stamp the
            # wrong sync_mode into checkpoint manifests)
            raise ValueError(
                "streaming fragments do not support outer compression "
                f"(streaming_fragments={self.streaming_fragments}, "
                f"compression={self.compression!r})"
            )
        if self.streaming_fragments > self.sync_every:
            # stride = max(H // P, 1) clamps to 1 and fragments collide on the
            # same step instead of spreading uniformly over the round
            raise ValueError(
                f"streaming_fragments ({self.streaming_fragments}) must be <= "
                f"sync_every ({self.sync_every}): with P > H the fragment "
                "stride degenerates to 1 and fragment syncs collide"
            )


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 1e-3            # gamma (inner lr)
    warmup_steps: int = 1000
    final_lr_ratio: float = 0.05     # cosine decays to 5% of peak (paper §3)
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-8
    weight_decay: float = -1.0       # -1 -> 1/T rule (Wang & Aitchison, paper §3)
    clip_norm: float = 1.0


@dataclass(frozen=True)
class TrainConfig:
    global_batch_tokens: int = 65536     # B, measured in tokens (paper convention)
    seq_len: int = 2048
    steps: int = 100
    microbatches: int = 1                # gradient-accumulation factor
    token_budget: int = 0                # 0 -> D = 20 * N * overtrain
    overtrain: float = 1.0               # lambda (paper §5.2)
    seed: int = 0
    eval_every: int = 0
    eval_batches: int = 4
    checkpoint_every: int = 0
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3
    log_every: int = 10

    @property
    def batch_sequences(self) -> int:
        return max(1, self.global_batch_tokens // self.seq_len)


@dataclass(frozen=True)
class MeshConfig:
    """Mesh shape: replica (DiLoCo/pod) x data (DP/FSDP) x model (TP)."""

    replica: int = 1
    data: int = 1
    model: int = 1
    axis_names: tuple = ("replica", "data", "model")

    @property
    def num_devices(self) -> int:
        return self.replica * self.data * self.model


@dataclass(frozen=True)
class ShapeSpec:
    """One dry-run cell: an input-shape regime for a given architecture."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int    # sequences
    kind: str            # train | prefill | decode


SHAPE_GRID = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPE_GRID:
        if s.name == name:
            return s
    raise KeyError(name)
