"""deepseek-67b [arXiv:2401.02954]: llama-arch dense, 95 layers, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab_size=102_400,
    act="silu",
    glu=True,
    tie_embeddings=False,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False,
)
