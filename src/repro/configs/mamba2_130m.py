"""mamba2-130m [arXiv:2405.21060]: attention-free SSD (state-space duality).
No FFN layers (d_ff=0): the block is mixer-only, per the Mamba architecture."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                   # attn-free, FFN-free: pure mamba blocks
    vocab_size=50_280,
    attn_layer_period=0,      # every layer is SSM
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    tie_embeddings=True,
    max_seq_len=1_048_576,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
    vocab_size=256, remat=False,
)
