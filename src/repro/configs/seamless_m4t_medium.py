"""seamless-m4t-medium [arXiv:2308.11596]: encoder-decoder multimodal; the
audio frontend is a STUB — input_specs() provides precomputed frame
embeddings (b, n_frames, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,              # decoder layers
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,            # MHA (assignment: GQA kv=16)
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    act="gelu",
    glu=False,
    frontend="audio_stub",
    n_frontend_tokens=1024,   # precomputed audio frame embeddings
    tie_embeddings=True,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256, n_frontend_tokens=16, remat=False,
)
