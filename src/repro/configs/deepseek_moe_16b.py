"""deepseek-moe-16b [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64
routed top-6 experts, first layer dense."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,          # MHA per assignment (GQA kv=16)
    head_dim=128,
    d_ff=1408,              # per-expert width (assignment's d_ff column)
    vocab_size=102_400,
    act="silu",
    glu=True,
    moe=True,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    moe_layer_freq=1,
    first_dense=1,          # deepseek-moe: leading dense layer
    dense_d_ff=10_944,      # dense-layer FFN width (paper's 0.5*4*d ratio x glu)
    tie_embeddings=False,
    max_seq_len=32_768,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=64, moe_d_ff=64, dense_d_ff=128, n_experts=4, top_k=2,
    n_shared_experts=1, vocab_size=256, moe_group_size=64, remat=False,
)
