"""Mamba-2 SSD intra-chunk Pallas kernel (TPU adaptation).

The GPU Mamba-2 kernels use a parallel associative scan; on TPU we use the
*dual (chunked) form*: within a chunk of Q tokens the SSM is two MXU matmuls
masked by the decay matrix L, plus a per-chunk state summary.  This kernel
computes, per (batch, chunk, head-tile):

    y_intra = (C B^T ⊙ L ⊙ dt) X          (Q x Q quadratic part)
    S_chunk = (B ⊙ dt·decay_to_end)^T X    (n x p state summary)
    decay   = exp(sum dA)                  (chunk decay factor)

The cheap inter-chunk recurrence (carry S across chunks) stays in jnp in
ops.py — it is O(h·p·n) per chunk and bandwidth-trivial.

Grid: (batch*chunks, head_tiles). Block = one chunk of HT heads:
VMEM per instance (Q=128, HT=8, p=64, n=128, fp32):
  x: 128*8*64*4 = 256KB; B,C: 128*8*128*4 = 512KB each; L/att: 128*128*8*4
  = 512KB; y: 256KB; S: 8*64*128*4 = 256KB  ->  ~2.3MB, fits v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, d_ref, *, q):
    # blocks: x (1, q, ht, p); dt (1, q, ht); a (ht,); b/c (1, q, ht, n)
    x = x_ref[0].astype(jnp.float32)          # (q, ht, p)
    dt = dt_ref[0].astype(jnp.float32)        # (q, ht)
    A = a_ref[...].astype(jnp.float32)        # (ht,)
    B = b_ref[0].astype(jnp.float32)          # (q, ht, n)
    C = c_ref[0].astype(jnp.float32)          # (q, ht, n)

    dA = dt * A[None, :]                      # (q, ht) <= 0
    cum = jnp.cumsum(dA, axis=0)              # (q, ht)
    total = cum[-1, :]                        # (ht,)

    # L[i, j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None, :] - cum[None, :, :]  # (q, q, ht)
    iq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where((iq >= jq)[:, :, None], jnp.exp(diff), 0.0)

    cb = jnp.einsum("ihn,jhn->ijh", C, B)     # (q, q, ht)
    w = cb * L * dt[None, :, :]               # weight for x_j
    y_ref[0] = jnp.einsum("ijh,jhp->ihp", w, x).astype(y_ref.dtype)

    decay_to_end = jnp.exp(total[None, :] - cum)         # (q, ht)
    wB = B * (dt * decay_to_end)[:, :, None]             # (q, ht, n)
    s_ref[0] = jnp.einsum("qhn,qhp->hpn", wB, x).astype(s_ref.dtype)
    d_ref[0] = jnp.exp(total).astype(d_ref.dtype)


def ssd_intra(x, dt, A, B, C, *, head_tile: int = 8, interpret: bool = True):
    """x: (BC, Q, H, P); dt: (BC, Q, H); A: (H,); B/C: (BC, Q, H, N)
    where BC = batch*chunks (chunks independent for the intra part).
    Returns (y_intra (BC,Q,H,P), S (BC,H,P,N), decay (BC,H), cum_exp? no).
    """
    bc, q, h, p = x.shape
    n = B.shape[-1]
    ht = min(head_tile, h)
    assert h % ht == 0, (h, ht)
    grid = (bc, h // ht)
    kernel = functools.partial(_ssd_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, ht, p), lambda b, t: (b, 0, t, 0)),
            pl.BlockSpec((1, q, ht), lambda b, t: (b, 0, t)),
            pl.BlockSpec((ht,), lambda b, t: (t,)),
            pl.BlockSpec((1, q, ht, n), lambda b, t: (b, 0, t, 0)),
            pl.BlockSpec((1, q, ht, n), lambda b, t: (b, 0, t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, ht, p), lambda b, t: (b, 0, t, 0)),
            pl.BlockSpec((1, ht, p, n), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, ht), lambda b, t: (b, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bc, q, h, p), x.dtype),
            jax.ShapeDtypeStruct((bc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((bc, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, B, C)
