"""Public SSD op: Pallas intra-chunk kernel + jnp inter-chunk recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import kernels
from repro.kernels.ssd_scan import ssd_scan as fk


def ssd_chunk_scan(x, dt, A, B, C, chunk: int, head_tile: int = 8):
    """Full SSD. x: (b, l, h, p); dt: (b, l, h); A: (h,); B/C: (b, l, g, n).
    Returns (y (b, l, h, p), final_state (b, h, p, n))."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = l // chunk
    assert l % chunk == 0
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)

    xc = x.reshape(b * nc, chunk, h, p)
    dtc = dt.reshape(b * nc, chunk, h)
    Bc = Bh.reshape(b * nc, chunk, h, n)
    Cc = Ch.reshape(b * nc, chunk, h, n)

    y_intra, S, decay = fk.ssd_intra(
        xc, dtc, A, Bc, Cc, head_tile=head_tile, interpret=kernels.INTERPRET
    )
    y_intra = y_intra.reshape(b, nc, chunk, h, p)
    S = S.reshape(b, nc, h, p, n)
    decay = decay.reshape(b, nc, h)

    # inter-chunk recurrence (cheap, jnp)
    def step(carry, inp):
        s_new, dec = inp
        s = carry * dec[:, :, None, None] + s_new
        return s, carry

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev = jax.lax.scan(
        step, s0, (jnp.moveaxis(S, 1, 0), jnp.moveaxis(decay, 1, 0))
    )
    prev = jnp.moveaxis(prev, 0, 1)                        # (b, nc, h, p, n)

    # y_inter = (C ⊙ exp(cum)) @ S_prev  — recompute cum cheaply in jnp
    dA = dt.astype(jnp.float32) * A.astype(jnp.float32)
    cum = jnp.cumsum(dA.reshape(b, nc, chunk, h), axis=2)
    wC = Ch.reshape(b, nc, chunk, h, n).astype(jnp.float32) * jnp.exp(cum)[..., None]
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", wC, prev)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(b, l, h, p)
    return y.astype(x.dtype), final.astype(x.dtype)
