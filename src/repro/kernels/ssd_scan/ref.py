"""Pure-jnp oracle: full SSD (chunked reference from models/mamba2.py)."""
from __future__ import annotations

from repro.models.mamba2 import ssd_chunked  # the framework's jnp reference


def ssd_ref(x, dt, A, B, C, chunk):
    """x: (b, l, h, p); dt: (b, l, h); A: (h,); B/C: (b, l, g, n)."""
    return ssd_chunked(x, dt, A, B, C, chunk)
