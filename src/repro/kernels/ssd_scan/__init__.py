from repro.kernels.ssd_scan.ops import ssd_chunk_scan  # noqa: F401
