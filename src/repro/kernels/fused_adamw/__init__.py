from repro.kernels.fused_adamw.ops import fused_adamw  # noqa: F401
