"""Public fused-AdamW op: pads/reshapes any tensor to (R, 128) lanes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import kernels
from repro.kernels.fused_adamw import fused_adamw as fk

LANES = fk.LANES


def _to_lanes(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // LANES)
    rows = -(-rows // fk.ROWS) * fk.ROWS  # pad to whole VMEM blocks
    pad = rows * LANES - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, LANES), n


def fused_adamw(p, g, m, v, *, lr, b1, b2, eps, weight_decay, bc1, bc2):
    """Fused AdamW step for one tensor. Returns (p', m', v')."""
    shape, dtype = p.shape, p.dtype
    p2, n = _to_lanes(p)
    g2, _ = _to_lanes(g)
    m2, _ = _to_lanes(m)
    v2, _ = _to_lanes(v)
    scalars = jnp.stack(
        [jnp.asarray(lr, jnp.float32), jnp.asarray(bc1, jnp.float32),
         jnp.asarray(bc2, jnp.float32), jnp.zeros((), jnp.float32)]
    ).reshape(1, 4)
    p3, m3, v3 = fk.adamw_blocks(
        p2, g2, m2, v2, scalars, b1=b1, b2=b2, eps=eps,
        weight_decay=weight_decay, interpret=kernels.INTERPRET,
    )
    unflat = lambda a, dt: a.reshape(-1)[:n].reshape(shape).astype(dt)
    return unflat(p3, dtype), unflat(m3, jnp.float32), unflat(v3, jnp.float32)
