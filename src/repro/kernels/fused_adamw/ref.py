"""Pure-jnp oracle for the fused AdamW kernel."""
from __future__ import annotations

import jax.numpy as jnp


def adamw_ref(p, g, m, v, *, lr, b1, b2, eps, weight_decay, bc1, bc2):
    g32 = g.astype(jnp.float32)
    m = b1 * m + (1.0 - b1) * g32
    v = b2 * v + (1.0 - b2) * jnp.square(g32)
    mhat = m / bc1
    vhat = v / bc2
    step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
    return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v
