"""Fused AdamW update kernel.

One pass over (param, grad, m, v) producing (param', m', v') — on TPU this
fuses what would otherwise be ~6 HBM round-trips of elementwise ops into a
single streamed read/write per tensor.  Tensors are flattened and tiled as
(rows, 128) lanes (VPU-aligned); traced scalars (lr and the bias-correction
terms, which depend on the step count) arrive via a small VMEM operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 256     # (256, 128) fp32 blocks: 128KB/operand in VMEM
LANES = 128


def _adamw_kernel(scalars_ref, p_ref, g_ref, m_ref, v_ref,
                  p_out, m_out, v_out, *, b1, b2, eps, weight_decay):
    lr = scalars_ref[0, 0]
    bc1 = scalars_ref[0, 1]
    bc2 = scalars_ref[0, 2]
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    p32 = p_ref[...].astype(jnp.float32)
    step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p32
    p_out[...] = (p32 - lr * step).astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


def adamw_blocks(p, g, m, v, scalars, *, b1, b2, eps, weight_decay,
                 interpret: bool = True):
    """All inputs (R, 128); scalars (1, 4) f32 = [lr, bc1, bc2, pad]."""
    rows = p.shape[0]
    nb = -(-rows // ROWS)
    kernel = functools.partial(
        _adamw_kernel, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay
    )
    blk = lambda i: (i, 0)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((ROWS, LANES), blk),
            pl.BlockSpec((ROWS, LANES), blk),
            pl.BlockSpec((ROWS, LANES), blk),
            pl.BlockSpec((ROWS, LANES), blk),
        ],
        out_specs=[
            pl.BlockSpec((ROWS, LANES), blk),
            pl.BlockSpec((ROWS, LANES), blk),
            pl.BlockSpec((ROWS, LANES), blk),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(m.shape, jnp.float32),
            jax.ShapeDtypeStruct(v.shape, jnp.float32),
        ],
        interpret=interpret,
    )(scalars, p, g, m, v)
