# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package ships three modules:
  * ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec
    VMEM tiling (TPU is the target; validated with ``interpret=True`` on CPU)
  * ``ops.py``    — the jit'd public wrapper (custom_vjp where trainable)
  * ``ref.py``    — the pure-jnp oracle used by the allclose test sweeps

Kernels: flash_attention (training/prefill hot spot), fused_adamw (inner
optimizer), outer_nesterov (DiLoCo outer step), delta_quant (int8 outer-Δ
compression for the cross-pod all-reduce), ssd_scan (Mamba-2 intra-chunk).
"""
INTERPRET = True  # CPU container: run kernels in interpret mode; False on TPU
