from repro.kernels.delta_quant.ops import quantize, dequantize  # noqa: F401
