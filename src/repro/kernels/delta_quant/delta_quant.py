"""Int8 block-quantization kernels for DiLoCo outer-Δ compression.

Symmetric int8 with one fp32 scale per (ROWS, 128) VMEM tile — the payload
crossing the cross-datacenter link is 1 byte/param + 4/(ROWS*128) bytes of
scale (vs 4 fp32 / 2 bf16), a 2-4x cut of the paper's Table-6 bandwidth
requirements on top of the 1/H factor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 256
LANES = 128


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def quant_blocks(x, *, interpret: bool = True):
    rows = x.shape[0]
    nb = -(-rows // ROWS)
    return pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequant_blocks(q, s, *, interpret: bool = True):
    rows = q.shape[0]
    nb = -(-rows // ROWS)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=interpret,
    )(q, s)
