"""Pure-jnp oracle for the int8 block-quant kernels."""
from __future__ import annotations

import jax.numpy as jnp

ROWS = 256
LANES = 128


def quantize_ref(x2d):
    """x2d: (R, 128) -> (q int8 (R,128), scales (ceil(R/ROWS), 1))."""
    rows = x2d.shape[0]
    nb = -(-rows // ROWS)
    pad = nb * ROWS - rows
    xp = jnp.pad(x2d, ((0, pad), (0, 0))).reshape(nb, ROWS, LANES).astype(jnp.float32)
    scales = jnp.maximum(jnp.abs(xp).max(axis=(1, 2)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xp / scales[:, None, None]), -127, 127).astype(jnp.int8)
    return q.reshape(nb * ROWS, LANES)[:rows], scales[:, None]


def dequantize_ref(q2d, scales):
    rows = q2d.shape[0]
    nb = scales.shape[0]
    pad = nb * ROWS - rows
    qp = jnp.pad(q2d, ((0, pad), (0, 0))).reshape(nb, ROWS, LANES)
    x = qp.astype(jnp.float32) * scales[:, :, None]
    return x.reshape(nb * ROWS, LANES)[:rows]
