"""Public int8 block-quant ops (any tensor shape)."""
from __future__ import annotations

import jax.numpy as jnp

from repro import kernels
from repro.kernels.delta_quant import delta_quant as fk

LANES = fk.LANES


def _to_lanes(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // LANES)
    rows = -(-rows // fk.ROWS) * fk.ROWS  # pad to whole VMEM blocks
    if rows * LANES - n:
        flat = jnp.pad(flat, (0, rows * LANES - n))
    return flat.reshape(rows, LANES), n


def quantize(x):
    """Returns (q int8 (R,128), scales (nb,1) f32, meta) for any-shape x."""
    x2, n = _to_lanes(x)
    q, s = fk.quant_blocks(x2, interpret=kernels.INTERPRET)
    return q, s, (x.shape, n)


def dequantize(q, s, meta, dtype=jnp.float32):
    shape, n = meta
    x = fk.dequant_blocks(q, s, interpret=kernels.INTERPRET)
    return x.reshape(-1)[:n].reshape(shape).astype(dtype)
