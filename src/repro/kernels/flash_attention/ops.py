"""Public flash-attention op: jit'd, differentiable (custom_vjp)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import kernels
from repro.kernels.flash_attention import flash_attention as fk


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128, bk: int = 128):
    """q: (BH, Sq, D); k/v: (BKV, Skv, D); BH % BKV == 0 (GQA)."""
    o, _ = _fwd(q, k, v, causal, bq, bk)
    return o


def _fwd(q, k, v, causal, bq, bk):
    group = q.shape[0] // k.shape[0]
    o, lse = fk.flash_fwd(
        q, k, v, causal=causal, group=group, bq=bq, bk=bk, interpret=kernels.INTERPRET
    )
    return o, (q, k, v, o, lse)


def _bwd(causal, bq, bk, res, do):
    q, k, v, o, lse = res
    group = q.shape[0] // k.shape[0]
    dq, dk_h, dv_h = fk.flash_bwd(
        q, k, v, o, lse, do, causal=causal, group=group, bq=bq, bk=bk,
        interpret=kernels.INTERPRET,
    )
    # dk/dv were computed per q-head: sum over the GQA group
    bkv, skv, d = k.shape
    dk = dk_h.reshape(bkv, group, skv, d).sum(axis=1).astype(k.dtype)
    dv = dv_h.reshape(bkv, group, skv, d).sum(axis=1).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_fwd, _bwd)


def mha_flash(q, k, v, *, causal: bool = True):
    """(b, t, nh, hd) x (b, s, nkv, hd) convenience wrapper."""
    b, t, nh, hd = q.shape
    s, nkv = k.shape[1], k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * nh, t, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * nkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * nkv, s, hd)
    of = flash_attention(qf, kf, vf, causal)
    return of.reshape(b, nh, t, hd).transpose(0, 2, 1, 3)
