"""Pure-jnp oracle for the flash-attention kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool):
    """q: (BH, Sq, D); k/v: (BKV, Skv, D); GQA group = BH // BKV."""
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    group = bh // bkv
    kr = jnp.repeat(k, group, axis=0)
    vr = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), jnp.bool_), k=skv - sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqk,bkd->bqd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)
