"""Flash attention Pallas TPU kernels (forward + backward).

Online-softmax tiling: grid (batch*q_heads, q_blocks, k_blocks), with the
k-block axis innermost; running (m, l, acc) state lives in VMEM scratch and
survives across k iterations of one q block.  Blocks are (BQ, head_dim) /
(BK, head_dim) — 128x128 by default, MXU-aligned.  GQA is handled in the
BlockSpec index maps (q head h reads kv head h // group) so K/V are never
materialized per-q-head.

VMEM budget per program instance (BQ=BK=128, hd<=256, fp32 scratch):
  q, k, v blocks: 3 * 128 * 256 * 2B = 192KB; acc/m/l: ~132KB; s/p: 64KB
  -> well under the ~16MB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
                *, scale, causal, bq, bk, n_k):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)

    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    # guard fully-masked blocks: exp(NEG_INF - NEG_INF) would be 1
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[..., 0] + jnp.log(l[..., 0])).astype(lse_ref.dtype)


def flash_fwd(q, k, v, *, causal: bool, group: int, bq: int = 128, bk: int = 128,
              interpret: bool = True):
    """q: (BH, Sq, D); k/v: (BKV, Skv, D) with BH = BKV * group.
    Returns (o (BH, Sq, D), lse (BH, Sq) fp32)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    n_q, n_k = sq // bq, skv // bk
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)

    kernel = functools.partial(
        _fwd_kernel, scale=d ** -0.5, causal=causal, bq=bq, bk=bk, n_k=n_k
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        scratch_shapes=_scratch(bq, d),
        interpret=interpret,
    )(q, k, v)


def _scratch(bq, d):
    from jax.experimental.pallas import tpu as pltpu

    return [
        pltpu.VMEM((bq, d), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
    ]


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   acc_ref, *, scale, causal, bq, bk, n_k):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]                                   # (bq,)
    delta = delta_ref[0]                               # (bq,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))   # (bq, bk)
    ds = p * (dp - delta[:, None]) * scale
    acc_ref[...] += jax.lax.dot(ds, k)

    @pl.when(ki == n_k - 1)
    def _done():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal, bq, bk, n_q, group):
    qi = pl.program_id(2)   # innermost: q blocks
    ki = pl.program_id(1)
    b = pl.program_id(0)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                                   # (bq, bk)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))  # (bk, d)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None]) * scale
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))  # (bk, d)

    @pl.when(qi == n_q - 1)
    def _done():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_bwd(q, k, v, o, lse, do, *, causal: bool, group: int,
              bq: int = 128, bk: int = 128, interpret: bool = True):
    """Returns (dq (BH,Sq,D), dk (BH,Skv,D)-per-q-head, dv same).

    dk/dv are computed per q-head and summed over the GQA group by the
    caller (ops.py) — keeps the kernel's write pattern conflict-free.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    n_q, n_k = sq // bq, skv // bk
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)  # (BH, Sq)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=d ** -0.5, causal=causal,
                          bq=bq, bk=bk, n_k=n_k),
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=_scratch(bq, d)[:1],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=d ** -0.5, causal=causal,
                          bq=bq, bk=bk, n_q=n_q, group=group),
        grid=(bh, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, skv, d), q.dtype),   # per-q-head dk
            jax.ShapeDtypeStruct((bh, skv, d), q.dtype),
        ],
        scratch_shapes=[_scratch(bk, d)[0], _scratch(bk, d)[0]],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
