"""Public fused outer-step op."""
from __future__ import annotations

import jax.numpy as jnp

from repro import kernels
from repro.kernels.outer_nesterov import outer_nesterov as fk

LANES = fk.LANES


def _to_lanes(x, lead=()):
    flat = x.reshape(*lead, -1)
    n = flat.shape[-1]
    rows = -(-n // LANES)
    rows = -(-rows // fk.ROWS) * fk.ROWS  # pad to whole VMEM blocks
    pad = rows * LANES - n
    if pad:
        flat = jnp.pad(flat, [(0, 0)] * len(lead) + [(0, pad)])
    return flat.reshape(*lead, rows, LANES), n


def outer_nesterov(g, deltas, m, *, lr, mu, nesterov=True):
    """g: params tensor; deltas: (M, *g.shape); m: fp32 momentum tensor."""
    shape, dtype = g.shape, g.dtype
    num = deltas.shape[0]
    g2, n = _to_lanes(g)
    d2, _ = _to_lanes(deltas, lead=(num,))
    m2, _ = _to_lanes(m)
    g3, m3 = fk.outer_blocks(
        g2, d2, m2, lr=lr, mu=mu, nesterov=nesterov, interpret=kernels.INTERPRET
    )
    unflat = lambda a, dt: a.reshape(-1)[:n].reshape(shape).astype(dt)
    return unflat(g3, dtype), unflat(m3, jnp.float32)
