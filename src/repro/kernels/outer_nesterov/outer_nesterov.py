"""Fused DiLoCo outer step kernel: Δ-average + Nesterov momentum + update.

Inputs: global params θ (R,128), the M per-replica deltas stacked (M,R,128)
(post all-reduce these are identical shards; pre-reduce this kernel also
fuses the local mean), momentum buffer (R,128).  One pass produces
(θ', momentum').  lr/μ are compile-time constants (paper: constant η).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 256
LANES = 128


def _outer_kernel(g_ref, d_ref, m_ref, g_out, m_out, *, lr, mu, nesterov, num_replicas):
    # d_ref: (M, ROWS, LANES) — fuse the replica mean with the update
    d = d_ref[...].astype(jnp.float32).sum(axis=0) * (1.0 / num_replicas)
    m_new = mu * m_ref[...] + d
    step = d + mu * m_new if nesterov else m_new
    g_out[...] = (g_ref[...].astype(jnp.float32) - lr * step).astype(g_out.dtype)
    m_out[...] = m_new


def outer_blocks(g, d, m, *, lr, mu, nesterov, interpret: bool = True):
    """g/m: (R, 128); d: (M, R, 128)."""
    rows = g.shape[0]
    num_replicas = d.shape[0]
    nb = -(-rows // ROWS)
    kernel = functools.partial(
        _outer_kernel, lr=lr, mu=mu, nesterov=nesterov, num_replicas=num_replicas
    )
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((num_replicas, ROWS, LANES), lambda i: (0, i, 0)),
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(g.shape, g.dtype),
            jax.ShapeDtypeStruct(m.shape, jnp.float32),
        ],
        interpret=interpret,
    )(g, d, m)
