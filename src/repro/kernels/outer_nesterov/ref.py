"""Pure-jnp oracle for the fused outer-Nesterov kernel."""
from __future__ import annotations

import jax.numpy as jnp


def outer_ref(g, deltas, m, *, lr, mu, nesterov):
    """g: params; deltas: (M, *g.shape); m: momentum fp32."""
    d = deltas.astype(jnp.float32).mean(axis=0)
    m_new = mu * m + d
    step = d + mu * m_new if nesterov else m_new
    return (g.astype(jnp.float32) - lr * step).astype(g.dtype), m_new
