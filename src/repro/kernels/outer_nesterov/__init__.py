from repro.kernels.outer_nesterov.ops import outer_nesterov  # noqa: F401
