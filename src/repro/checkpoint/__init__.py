from repro.checkpoint.checkpointer import (  # noqa: F401
    SCHEMA_VERSION,
    Checkpointer,
    CorruptCheckpointError,
    config_fingerprint,
)
