"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic-restore.

Layout: ``<dir>/step_<n>/state.npz`` + ``manifest.json``.  Writes go to a
``.tmp`` sibling then ``os.replace`` (atomic on POSIX) — a crash mid-save
never corrupts the latest checkpoint.  ``save_async`` offloads serialization
to a daemon thread so the train loop keeps stepping (save is snapshotted
to host numpy first).

Elastic restore: DiLoCo state saved with M replicas can be restored with a
different M' — new replicas bootstrap from the global model and fresh inner
optimizer state (the paper's outer state is global-shaped, so momentum is
carried exactly).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: dict):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[Exception] = None

    # ---- sync ------------------------------------------------------------
    def save(self, state: Any, step: int) -> str:
        flat = _flatten(state)
        return self._write(flat, step)

    def _write(self, flat: dict, step: int) -> str:
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "state.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(flat)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    # ---- async ---------------------------------------------------------------
    def save_async(self, state: Any, step: int) -> None:
        if self._error is not None:
            raise self._error
        flat = _flatten(jax.tree.map(lambda x: np.asarray(x), state))  # snapshot now
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()
        self._q.put((flat, step))

    def _drain(self):
        while True:
            try:
                flat, step = self._q.get(timeout=1.0)
            except queue.Empty:
                return
            try:
                self._write(flat, step)
            except Exception as e:  # surfaced on next save_async
                self._error = e
            finally:
                self._q.task_done()

    def wait(self):
        if self._worker is not None and self._worker.is_alive():
            self._q.join()
        if self._error is not None:
            raise self._error

    # ---- restore -----------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        ]
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}", "state.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten(template, flat), step

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.dir) if d.startswith("step_") and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, d))
