"""Versioned, sharding-aware, elastic checkpoint/restore subsystem.

Layout: ``<dir>/step_<n>/state.npz`` + ``manifest.json``.  Writes land in a
``.tmp`` sibling that is fsynced (files, then the tmp dir, then the parent
dir after the rename) before an atomic ``os.replace`` — a crash mid-save can
never corrupt the newest checkpoint, and orphaned ``.tmp`` dirs from a crash
are reaped on the next ``Checkpointer(...)`` construction.

Manifest schema v3 records everything needed to restore without a live
template: schema version, step, per-leaf dtypes/shapes, per-leaf content
**checksums**, ``num_replicas``, the sync mode — the trainer's
``SyncStrategy`` manifest tag (``none`` / ``int8`` / ``streaming`` /
``dp`` / ``int4`` / any registered strategy's; ``repro.core.sync.from_tag``
maps a tag back to its strategy class, with ``"none"`` permanently aliased
to the full-precision strategy) — and a config fingerprint.  v1
directories (``{"step", "keys"}`` only) and v2 (no checksums) still load.

Hardened I/O (fault-tolerant runtime): every payload read/write is wrapped
in ``repro.core.retry`` bounded exponential backoff, and checks
``repro.core.faults.io_check`` so chaos schedules can inject transient
``OSError``s.  On restore, v3 checksums are verified leaf-by-leaf; a
checkpoint that fails verification (bit rot, torn write, truncated zip)
raises ``CorruptCheckpointError`` — and a *latest*-checkpoint restore
falls back to the newest older intact checkpoint with a warning, so a
single corrupt save never strands a resumable run.  An explicit
``restore(step=...)`` never falls back silently.

Restore paths:

* ``restore(template)`` — legacy exact-shape path: leaves are cast onto the
  template's dtypes.
* ``restore()`` with ``Checkpointer(dir, trainer=...)`` — template-free: the
  tree *structure* comes from ``DiLoCo.abstract_state()``, the leaf values
  and dtypes come from the checkpoint itself (bitwise-exact), and every leaf
  is ``jax.device_put`` onto the current mesh via
  ``trainer.state_partition_specs()`` — restored state is a committed,
  sharded device tree, safe to hand straight to donating executables.
* ``restore(num_replicas=M')`` — elastic: the saved M-replica state is
  resized between outer rounds.  Surviving replicas keep their inner
  optimizer state; fresh replicas start from the global params with zeroed
  AdamW moments and a **zeroed** Adam ``count`` (cold-start bias
  correction), and int8 error-feedback slices are grown/shrunk in step.

``save_async`` snapshots the (possibly donated) device state to host numpy
synchronously, then hands it to a persistent writer thread through a
bounded queue (backpressure instead of unbounded host-RAM growth).  The
worker only ever exits on an explicit sentinel (``close()``), so
``wait()`` — a ``Queue.join()`` — is deterministic: it returns only after
every enqueued checkpoint is on disk, and re-raises any writer error.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import queue
import shutil
import threading
import warnings
import zipfile
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.core import faults, retry

SCHEMA_VERSION = 3

_SENTINEL = object()


class CorruptCheckpointError(RuntimeError):
    """A checkpoint failed content verification (missing payload, unreadable
    archive, or a manifest-v3 per-leaf checksum mismatch)."""


def _digest(arr: np.ndarray) -> str:
    """Content checksum of one leaf (dtype/shape are manifested separately)."""
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: dict, *, cast: bool = True):
    """Rebuild ``template``'s structure from ``flat``.

    ``cast=True`` (legacy template path) casts onto the template leaf dtype;
    ``cast=False`` (abstract-structure path) keeps the stored arrays
    bitwise-exact — the template only supplies the treedef and key order.
    """
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        if key not in flat:
            raise KeyError(
                f"checkpoint is missing leaf {key!r} required by the current "
                f"config (stored keys: {sorted(flat)[:8]}...) — was it saved "
                "under a different sync mode?"
            )
        arr = flat[key]
        if cast and hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directory fds are valid on POSIX)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def config_fingerprint(trainer) -> str:
    """Stable digest of the run configuration: model + algorithm + optimizer
    + train schedule (steps/batch/seq_len/seed — these feed the lr schedule
    and data stream, so changing them breaks exact resume).

    ``num_replicas`` is deliberately excluded: elastic M -> M' restore is a
    supported operation, not a config mismatch.  The algorithm section is
    canonicalized by the sync strategy (``SyncStrategy.fingerprint_fields``):
    a config spelled through the legacy flags and one spelled through
    ``sync="..."`` digest identically, and both match pre-strategy
    checkpoints, so the migration never trips the drift warning.
    """
    payload = {
        "model": dataclasses.asdict(trainer.model.cfg),
        "diloco": trainer.sync.fingerprint_fields(trainer.dcfg),
        "optimizer": dataclasses.asdict(trainer.ocfg),
        "train": {
            k: getattr(trainer.tcfg, k)
            for k in ("global_batch_tokens", "seq_len", "steps", "microbatches", "seed")
        },
    }
    blob = json.dumps(payload, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


class Checkpointer:
    """Atomic, async, keep-k, elastic checkpointing (see module docstring).

    ``trainer`` (a ``repro.core.diloco.DiLoCo``) enables the v2 manifest
    metadata and template-free / elastic ``restore()``; without it the
    Checkpointer still saves v2 manifests (minus config metadata) and
    restores via the legacy template path.
    """

    def __init__(
        self,
        directory: str,
        keep: int = 3,
        *,
        trainer: Any = None,
        max_inflight: int = 2,
        retry_policy: Optional[retry.Policy] = None,
    ):
        self.dir = directory
        self.keep = keep
        self.trainer = trainer
        self._retry = retry_policy if retry_policy is not None else retry.Policy()
        os.makedirs(directory, exist_ok=True)
        self._reap_tmp()
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, max_inflight))
        self._worker: Optional[threading.Thread] = None
        self._worker_lock = threading.Lock()
        self._error: Optional[Exception] = None

    def _reap_tmp(self) -> None:
        for d in os.listdir(self.dir):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---- manifest --------------------------------------------------------
    def _manifest(self, flat: dict, step: int) -> dict:
        man = {
            "schema": SCHEMA_VERSION,
            "step": step,
            "keys": sorted(flat),
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            # v3: per-leaf content checksums, verified on restore
            "checksums": {k: _digest(v) for k, v in flat.items()},
        }
        if self.trainer is not None:
            man["num_replicas"] = int(self.trainer.M)
            man["sync_mode"] = self.trainer.sync_mode
            man["fingerprint"] = config_fingerprint(self.trainer)
        return man

    def _read_manifest(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:010d}", "manifest.json")
        if not os.path.exists(path):
            return {"schema": 1, "step": step}
        with open(path) as f:
            man = json.load(f)
        man.setdefault("schema", 1)
        return man

    # ---- sync ------------------------------------------------------------
    def save(self, state: Any, step: int) -> str:
        flat = _flatten(state)
        return self._write(flat, step)

    def _write(self, flat: dict, step: int) -> str:
        # _write_once is restartable from scratch (the .tmp staging dir is
        # rebuilt per attempt), so transient OSErrors — real or injected via
        # repro.core.faults — are absorbed by bounded backoff.
        return retry.call(
            lambda: self._write_once(flat, step),
            policy=self._retry,
            retry_on=(OSError,),
            on_retry=lambda n, e: warnings.warn(
                f"checkpoint save (step {step}) attempt {n} failed: {e}; retrying",
                stacklevel=2,
            ),
        )

    def _write_once(self, flat: dict, step: int) -> str:
        faults.io_check("checkpoint_save")
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        npz = os.path.join(tmp, "state.npz")
        np.savez(npz, **flat)
        _fsync_path(npz)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(self._manifest(flat, step), f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(tmp)
        if os.path.exists(final):
            # never rmtree the published dir before the new one is in place:
            # move it aside first so a crash anywhere in this window leaves
            # either the old or the new checkpoint (the .tmp suffix keeps it
            # invisible to latest_step and reaped by the next __init__)
            old = final + ".old.tmp"
            if os.path.exists(old):
                shutil.rmtree(old)
            os.replace(final, old)
            os.replace(tmp, final)
            _fsync_path(self.dir)  # durably publish the rename
            shutil.rmtree(old)
        else:
            os.replace(tmp, final)
            _fsync_path(self.dir)
        # chaos hook: scheduled payload corruption lands AFTER the atomic
        # publish, modelling bit rot the filesystem never sees
        faults.on_checkpoint_written(final, step)
        self._gc()
        return final

    # ---- async -----------------------------------------------------------
    def save_async(self, state: Any, step: int) -> None:
        """Snapshot ``state`` to host numpy NOW (so the caller may donate it
        immediately afterwards) and enqueue the write.  Blocks only when
        ``max_inflight`` saves are already pending (backpressure)."""
        self._raise_pending()
        flat = _flatten(state)  # device -> host snapshot before returning
        self._ensure_worker()
        self._q.put((flat, step))

    def _ensure_worker(self) -> None:
        with self._worker_lock:
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(
                    target=self._drain, name="ckpt-writer", daemon=True
                )
                self._worker.start()

    def _drain(self) -> None:
        # Persistent worker: runs until it sees the shutdown sentinel.  There
        # is no idle timeout, so there is no window in which save_async can
        # observe a live worker that is about to exit (the old TOCTOU race
        # that could strand the final checkpoint in the queue forever).
        while True:
            item = self._q.get()
            try:
                if item is _SENTINEL:
                    return
                flat, step = item
                self._write(flat, step)
            except Exception as e:  # re-raised by wait()/next save_async
                self._error = e
            finally:
                self._q.task_done()

    def wait(self) -> None:
        """Block until every enqueued save is durably on disk; re-raise any
        writer error.  Deterministic: the worker never exits on its own, so
        ``Queue.join()`` cannot return with items still stranded."""
        if self._worker is not None:
            self._q.join()
        self._raise_pending()

    def close(self) -> None:
        """Drain pending saves, then shut the writer thread down."""
        with self._worker_lock:
            worker, self._worker = self._worker, None
        if worker is not None and worker.is_alive():
            self._q.put(_SENTINEL)
            self._q.join()
            worker.join()
        self._raise_pending()

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ---- restore ---------------------------------------------------------
    def _steps(self) -> List[int]:
        return sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return steps[-1] if steps else None

    def _load_verified(self, step: int) -> Tuple[dict, dict]:
        """Load + verify one checkpoint's payload and manifest.

        Transient read errors are retried; anything that survives the
        retries — or a v3 per-leaf checksum mismatch — raises
        ``CorruptCheckpointError``.  v1/v2 manifests (no checksums) load
        without content verification.
        """
        path = os.path.join(self.dir, f"step_{step:010d}", "state.npz")
        if not os.path.exists(path):
            raise CorruptCheckpointError(f"missing payload {path}")

        def read():
            faults.io_check("checkpoint_restore")
            with np.load(path) as z:
                return {k: z[k] for k in z.files}

        try:
            flat = retry.call(read, policy=self._retry, retry_on=(OSError,))
        except (OSError, ValueError, KeyError, EOFError, zipfile.BadZipFile) as e:
            raise CorruptCheckpointError(f"unreadable payload {path}: {e}") from e
        try:
            manifest = self._read_manifest(step)
        except (OSError, ValueError) as e:  # json.JSONDecodeError is a ValueError
            raise CorruptCheckpointError(
                f"unreadable manifest for step {step}: {e}"
            ) from e
        for key, want in sorted(manifest.get("checksums", {}).items()):
            if key not in flat:
                raise CorruptCheckpointError(
                    f"step {step}: leaf {key!r} missing from payload"
                )
            got = _digest(flat[key])
            if got != want:
                raise CorruptCheckpointError(
                    f"step {step}: leaf {key!r} checksum {got} != manifest "
                    f"{want} (v3 content verification)"
                )
        return flat, manifest

    def restore(
        self,
        template: Any = None,
        step: Optional[int] = None,
        *,
        num_replicas: Optional[int] = None,
        strict_fingerprint: bool = False,
    ) -> Tuple[Any, int]:
        """Restore a checkpoint; see the module docstring for the three
        modes (template / template-free / elastic).  Returns (state, step)."""
        explicit = step is not None
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        while True:
            try:
                flat, manifest = self._load_verified(step)
                break
            except CorruptCheckpointError as e:
                if explicit:
                    # the caller named this step; falling back silently
                    # would resume from somewhere they did not ask for
                    raise
                older = [s for s in self._steps() if s < step]
                if not older:
                    raise CorruptCheckpointError(
                        f"no intact checkpoint in {self.dir} "
                        f"(newest failure: {e})"
                    ) from e
                warnings.warn(
                    f"checkpoint step {step} failed verification ({e}); "
                    f"falling back to the last intact checkpoint "
                    f"(step {max(older)})",
                    stacklevel=2,
                )
                step = max(older)
        self._sync_hparams(flat, template)

        if template is not None:
            if num_replicas is not None:
                raise ValueError(
                    "restore(template=..., num_replicas=...) is ambiguous: "
                    "elastic restore requires the template-free trainer path "
                    "(Checkpointer(dir, trainer=...).restore(num_replicas=M'))"
                )
            return _unflatten(template, flat, cast=True), step

        if self.trainer is None:
            raise ValueError(
                "template-free restore requires Checkpointer(dir, trainer=...)"
            )
        trainer = self.trainer
        self._check_fingerprint(manifest, strict_fingerprint)

        # Structure from abstract_state(); values/dtypes bitwise from disk.
        abstract = trainer.abstract_state()
        state = _unflatten(abstract, flat, cast=False)

        saved_m = manifest.get("num_replicas")
        if saved_m is None:  # v1 manifest: infer from the replica axis
            saved_m = int(flat["inner_opt/count"].shape[0])
        target_m = int(num_replicas) if num_replicas is not None else trainer.M
        if target_m != saved_m:
            if not trainer.sync.uses_outer_opt:
                raise ValueError(
                    f"cannot elastically restore a data-parallel run "
                    f"(saved M={saved_m}, requested M'={target_m})"
                )
            from repro.core import elastic

            state = elastic.resize_replicas(trainer, state, target_m)
        return self._device_put(state, trainer), step

    def _sync_hparams(self, flat: dict, template: Any = None) -> None:
        """Make the restored ``hparams`` leaves reflect the CURRENT config.

        Two cases in one: (a) migration — checkpoints written before the
        state carried an ``hparams`` leaf lack ``hparams/*`` keys entirely;
        (b) config drift — the run was relaunched with a different lr /
        outer-lr.  Either way the current trainer config wins (the
        pre-traced-hparams behavior, where relaunching with ``--lr`` baked
        the new value into fresh executables).  For a same-config resume
        the values are identical to what was saved, so exact resume is
        unaffected; for changed configs the fingerprint warning already
        fires."""
        src = None
        if self.trainer is not None:
            src = {"hparams": self.trainer.hparams()}
        elif isinstance(template, dict) and "hparams" in template:
            src = {"hparams": template["hparams"]}
        if src is None:
            return
        try:
            current = _flatten(src)
        except Exception:  # abstract template leaves have no values
            return
        for k, v in current.items():
            flat[k] = v

    def _check_fingerprint(self, manifest: dict, strict: bool) -> None:
        saved = manifest.get("fingerprint")
        if saved is None:
            return
        current = config_fingerprint(self.trainer)
        if saved != current:
            msg = (
                f"checkpoint config fingerprint {saved} != current {current}: "
                "the run configuration changed since this checkpoint was "
                "saved (model / optimizer / sync mode / train schedule — "
                "steps, batch, seq_len, seed — drift?); resumed training "
                "will not be an exact continuation"
            )
            if strict:
                raise ValueError(msg)
            warnings.warn(msg, stacklevel=3)

    def _device_put(self, state: Any, trainer: Any):
        """Place every leaf on device — sharded per the trainer's partition
        specs when a mesh is active — so the restored tree is committed and
        donation-safe (host numpy leaves are not)."""
        from repro import sharding

        mesh = sharding.current_mesh()
        if mesh is not None and sharding.current_rules():
            shardings = sharding.tree_named(mesh, trainer.state_partition_specs())
            return jax.tree.map(jax.device_put, state, shardings)
        return jax.tree.map(jax.device_put, state)

    def _gc(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.dir) if d.startswith("step_") and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, d))
