"""Deterministic, shardable LM data pipeline.

Two sources:

* ``SyntheticLM`` — a mixture of hidden-domain Markov chains with Zipf-ish
  marginals.  Learnable structure (in-context domain inference + per-domain
  transition tables) so eval loss decreases with model capacity — this is
  the container-offline stand-in for C4/Dolma (see DESIGN.md §9).
* ``TokenFileSource`` — memory-mapped binary token files for real corpora.

Both are *stateless*: ``batch(step, replica, ...)`` is a pure function of
its arguments, so checkpoint/restart resumes the stream exactly (the data
cursor IS the step counter), and each DiLoCo replica m reads its own shard
D_m (paper Algorithm 1 line 4) by folding the replica id into the PRNG key.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnums=(2, 3))
def synthetic_tokens(logits: jax.Array, key: jax.Array, n_seqs: int, seq_len: int) -> jax.Array:
    """Generate ``(n_seqs, seq_len+1)`` tokens from per-domain transition
    logits of shape ``(n_domains, vocab, vocab)``.

    Pure function of its operands: the transition table is an argument, not
    a closure constant, so one compiled executable serves every
    ``SyntheticLM`` instance with the same shapes (sweep cells differing
    only in ``seed`` stop recompiling), and the cell-batched engine can
    ``vmap`` it over a stacked per-cell table axis.
    """
    n_domains, vocab_size = logits.shape[0], logits.shape[1]
    kd, k0, kc = jax.random.split(key, 3)
    domains = jax.random.randint(kd, (n_seqs,), 0, n_domains)
    first = jax.random.randint(k0, (n_seqs,), 0, vocab_size)
    table = logits[domains]  # (n, V, V)

    def step(tok, k):
        nxt = jax.random.categorical(k, jnp.take_along_axis(
            table, tok[:, None, None], axis=1)[:, 0, :])
        return nxt, nxt

    keys = jax.random.split(kc, seq_len)
    _, seq = jax.lax.scan(step, first, keys)
    return jnp.concatenate([first[None], seq], axis=0).T  # (n, L+1)


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int = 256
    seq_len: int = 256
    n_domains: int = 8
    temperature: float = 1.2
    seed: int = 1234
    eval_offset: int = 1 << 30   # eval stream lives in a disjoint key region

    def __post_init__(self):
        root = jax.random.PRNGKey(self.seed)
        k_trans, k_marg = jax.random.split(root)
        # per-domain transition logits, sparsified so chains are learnable
        logits = jax.random.normal(
            k_trans, (self.n_domains, self.vocab_size, self.vocab_size)
        ) * self.temperature
        # Zipf-flavored marginal bias shared across domains
        zipf = -jnp.log(jnp.arange(1, self.vocab_size + 1, dtype=jnp.float32))
        self._logits = logits + 0.5 * zipf[None, None, :]
        self._root = root

    # -- internals ---------------------------------------------------------
    def _gen(self, key: jax.Array, n_seqs: int) -> jax.Array:
        """Generate (n_seqs, seq_len+1) tokens (traceable; shares the
        module-level ``synthetic_tokens`` executable across instances)."""
        return synthetic_tokens(self._logits, key, n_seqs, self.seq_len)

    # -- public API ------------------------------------------------------------
    def batch(self, step: int, replica: int, num_replicas: int, batch_seqs: int, *, eval: bool = False) -> dict:
        """Batch for one replica at one step: {"tokens","labels"} (b, seq_len)."""
        key = self._root
        if eval:
            key = jax.random.fold_in(key, self.eval_offset)
        key = jax.random.fold_in(key, int(step))
        key = jax.random.fold_in(key, int(replica) + num_replicas * 7919)
        toks = self._gen(key, batch_seqs)
        return {"tokens": toks[:, :-1].astype(jnp.int32), "labels": toks[:, 1:].astype(jnp.int32)}

    def global_batch(self, step: int, num_replicas: int, batch_seqs_per_replica: int, *, eval: bool = False) -> dict:
        """Stacked per-replica batches: leading axis M (DiLoCo data shards)."""
        bs = [
            self.batch(step, m, num_replicas, batch_seqs_per_replica, eval=eval)
            for m in range(num_replicas)
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)

    def entropy_floor(self, n_samples: int = 4096) -> float:
        """Monte-Carlo conditional entropy of the source = best achievable nll."""
        probs = jax.nn.softmax(self._logits, axis=-1)
        h = -(probs * jnp.log(probs + 1e-20)).sum(-1)  # (D, V)
        return float(h.mean())


@dataclasses.dataclass
class TokenFileSource:
    """Memory-mapped uint16/uint32 token file, chunked into sequences.

    The trailing ``eval_frac`` of sequences is held out: ``eval=True``
    batches draw only from that tail, training batches only from the head,
    so reported eval numbers measure generalization, not memorization.
    """

    path: str
    seq_len: int
    dtype: str = "uint16"
    eval_frac: float = 0.05

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        n = (len(self._data) - 1) // self.seq_len
        n_eval = min(max(int(n * self.eval_frac), 1), n - 1) if n > 1 else 0
        self._n_seqs = n - n_eval      # training pool (head of the file)
        self._n_eval = n_eval          # held-out pool (tail of the file)

    def batch(self, step: int, replica: int, num_replicas: int, batch_seqs: int, *, eval: bool = False) -> dict:
        # replica-strided disjoint shards; deterministic in (step, replica)
        base = (step * num_replicas + replica) * batch_seqs
        if eval and self._n_eval > 0:
            idx = self._n_seqs + (base + np.arange(batch_seqs)) % self._n_eval
        else:
            idx = (base + np.arange(batch_seqs)) % self._n_seqs
        starts = idx * self.seq_len
        toks = np.stack([self._data[s : s + self.seq_len + 1] for s in starts]).astype(np.int32)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    def global_batch(self, step: int, num_replicas: int, batch_seqs_per_replica: int, *, eval: bool = False) -> dict:
        bs = [
            self.batch(step, m, num_replicas, batch_seqs_per_replica, eval=eval)
            for m in range(num_replicas)
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
