from repro.data.pipeline import SyntheticLM, TokenFileSource  # noqa: F401
