from repro.data.pipeline import SyntheticLM, TokenFileSource, synthetic_tokens  # noqa: F401
