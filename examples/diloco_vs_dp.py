"""Paper Finding 2 in miniature: DiLoCo M=1 (Lookahead variant) vs
Data-Parallel at identical token budget, plus batch-size robustness.

  PYTHONPATH=src python examples/diloco_vs_dp.py
"""
import jax
import numpy as np

from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core.diloco import make_trainer
from repro.core.superstep import SuperstepEngine
from repro.data import SyntheticLM
from repro.models import build_model

cfg = get_config("tiny-t0")
model = build_model(cfg)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128)
TOKENS = 400_000


def run(algo, m=1, batch_tokens=4096, h=15):
    steps = TOKENS // batch_tokens
    trainer = make_trainer(
        model,
        DiLoCoConfig(num_replicas=m, sync_every=h, data_parallel=(algo == "dp")),
        OptimizerConfig(peak_lr=3e-3, warmup_steps=max(steps // 10, 1)),
        TrainConfig(global_batch_tokens=batch_tokens, seq_len=128, steps=steps),
    )
    state = trainer.init_state(jax.random.PRNGKey(0))
    # superstep engine: one compiled round per dispatch (state is donated)
    engine = SuperstepEngine(trainer, data, batch_tokens // 128 // trainer.M)
    state, _ = engine.run(state, steps)
    if algo == "diloco" and steps % h != 0:
        state = trainer.jit_outer_sync()(state)  # sync the partial tail round
    evals = [float(trainer.eval_step(state, data.batch(10_000 + i, 0, 1, 16, eval=True)))
             for i in range(6)]
    return float(np.mean(evals))


print(f"{'batch':>8s} {'Data-Parallel':>14s} {'DiLoCo M=1':>12s} {'DiLoCo M=2':>12s}")
for b in (2048, 8192):
    dp = run("dp", batch_tokens=b)
    m1 = run("diloco", m=1, batch_tokens=b)
    m2 = run("diloco", m=2, batch_tokens=b)
    print(f"{b:8d} {dp:14.4f} {m1:12.4f} {m2:12.4f}")
print("\n(paper Findings 2-3: M=1 matches/beats DP; DiLoCo degrades less "
      "as batch grows)")
