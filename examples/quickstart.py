"""Quickstart: train a small LM with DiLoCo (M=2 replicas) on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core.diloco import make_trainer
from repro.core.superstep import SuperstepEngine
from repro.data import SyntheticLM
from repro.models import build_model

# 1. pick an architecture from the registry (any of the 10 assigned archs
#    works via get_smoke_config; the tiny-* family trains in seconds)
cfg = get_config("tiny-t0")
model = build_model(cfg)
print(f"model {cfg.name}: {model.param_count()/1e3:.0f}k params")

# 2. configure the paper's algorithm: M replicas, sync every H steps,
#    AdamW inner / Nesterov outer (Algorithm 1)
trainer = make_trainer(
    model,
    DiLoCoConfig(num_replicas=2, sync_every=10, outer_lr=0.7),
    OptimizerConfig(peak_lr=3e-3, warmup_steps=20),
    TrainConfig(global_batch_tokens=4096, seq_len=128, steps=100),
)

# 3. data: each replica m reads its own shard D_m (Algorithm 1 line 4)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128)

# 4. train with the superstep engine: each call runs a whole outer round
#    (H inner steps + the outer sync — the ONLY cross-replica communication)
#    as ONE compiled executable, with batches generated on device and the
#    host syncing once per round.  NB the state argument is donated
#    (updated in place): always rebind it, never reuse the old reference.
state = trainer.init_state(jax.random.PRNGKey(0))
engine = SuperstepEngine(trainer, data, batch_seqs=2)
for rnd in range(10):  # 100 steps = 10 rounds of H=10
    state, metrics = engine.run_round(state, start=rnd * 10)
    if (rnd + 1) % 2 == 0:
        print(f"step {(rnd+1) * 10}: loss={metrics['loss'][-1]:.4f}")

# 5. evaluate the global model (paper §2.2)
eval_nll = trainer.eval_step(state, data.batch(10_000, 0, 1, 8, eval=True))
print(f"eval nll: {float(eval_nll):.4f} (source floor ~{data.entropy_floor():.4f})")
