"""Quickstart: train a small LM with DiLoCo (M=2 replicas) on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core.diloco import make_trainer
from repro.data import SyntheticLM
from repro.models import build_model

# 1. pick an architecture from the registry (any of the 10 assigned archs
#    works via get_smoke_config; the tiny-* family trains in seconds)
cfg = get_config("tiny-t0")
model = build_model(cfg)
print(f"model {cfg.name}: {model.param_count()/1e3:.0f}k params")

# 2. configure the paper's algorithm: M replicas, sync every H steps,
#    AdamW inner / Nesterov outer (Algorithm 1)
trainer = make_trainer(
    model,
    DiLoCoConfig(num_replicas=2, sync_every=10, outer_lr=0.7),
    OptimizerConfig(peak_lr=3e-3, warmup_steps=20),
    TrainConfig(global_batch_tokens=4096, seq_len=128, steps=100),
)

# 3. data: each replica m reads its own shard D_m (Algorithm 1 line 4)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128)

# 4. train: inner steps every step, outer sync every H
state = trainer.init_state(jax.random.PRNGKey(0))
inner = jax.jit(trainer.inner_step)
outer = jax.jit(trainer.outer_sync)
for step in range(100):
    batch = data.global_batch(step, trainer.M, batch_seqs_per_replica=2)
    state, metrics = inner(state, batch)
    if (step + 1) % trainer.dcfg.sync_every == 0:
        state = outer(state)  # the ONLY cross-replica communication
    if (step + 1) % 20 == 0:
        print(f"step {step+1}: loss={float(metrics['loss']):.4f}")

# 5. evaluate the global model (paper §2.2)
eval_nll = trainer.eval_step(state, data.batch(10_000, 0, 1, 8, eval=True))
print(f"eval nll: {float(eval_nll):.4f} (source floor ~{data.entropy_floor():.4f})")
