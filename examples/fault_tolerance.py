"""Fault-tolerance walkthrough: checkpoint/restart, straggler dropout, and
elastic replica scaling — the 1000-node story at toy scale.

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core import elastic
from repro.core.diloco import make_trainer
from repro.data import SyntheticLM
from repro.models import build_model

cfg = get_config("tiny-t0")
model = build_model(cfg)
trainer = make_trainer(
    model,
    DiLoCoConfig(num_replicas=4, sync_every=5),
    OptimizerConfig(peak_lr=3e-3, warmup_steps=10),
    TrainConfig(global_batch_tokens=4096, seq_len=128, steps=40),
)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128)
# donated entry points: each call consumes its state argument in place
inner, outer = trainer.jit_inner_step(), trainer.jit_outer_sync()

with tempfile.TemporaryDirectory() as tmp:
    ck = Checkpointer(tmp, keep=2, trainer=trainer)
    state = trainer.init_state(jax.random.PRNGKey(0))

    # --- phase 1: train 10 steps, async-checkpoint, "crash" -------------
    for t in range(10):
        state, m = inner(state, data.global_batch(t, 4, 2))
        if (t + 1) % 5 == 0:
            state = outer(state)
            ck.save_async(state, t + 1)
    ck.wait()
    print(f"crashed at step 10; checkpoints: {sorted(os.listdir(tmp))}")

    # --- phase 2: restart from the latest checkpoint ---------------------
    # template-free: structure from trainer.abstract_state(), values bitwise
    # from disk, leaves device_put (donation-safe)
    state, start = ck.restore()
    print(f"restored at step {start}; data pipeline resumes exactly "
          f"(stateless, step-indexed)")

    # --- phase 3: replica 3 straggles -> drop it from the outer sync ------
    for t in range(start, start + 5):
        state, m = inner(state, data.global_batch(t, 4, 2))
    mask = jnp.array([True, True, True, False])     # replica 3 missed deadline
    state = outer(state, elastic.participation_weights(mask))
    print(f"outer sync with straggler dropped: loss={float(m['loss']):.4f}")

    # --- phase 4: elastic scale-down to 2 replicas, then scale up to 4 ----
    # (same machinery Checkpointer.restore(num_replicas=M') uses; fresh
    # replicas would get global params + cold-start AdamW state)
    state2 = elastic.resize_replicas(trainer, state, 2)
    print(f"scaled M 4->2: inner leading dims now "
          f"{jax.tree.leaves(state2['inner_params'])[0].shape[0]}")
    trainer2 = make_trainer(
        model, DiLoCoConfig(num_replicas=2, sync_every=5),
        OptimizerConfig(peak_lr=3e-3, warmup_steps=10),
        TrainConfig(global_batch_tokens=4096, seq_len=128, steps=40),
    )
    inner2 = trainer2.jit_inner_step()
    for t in range(15, 20):
        state2, m = inner2(state2, data.global_batch(t, 2, 4))
    state2 = trainer2.outer_sync(state2)
    ev = trainer2.eval_step(state2, data.batch(10_000, 0, 1, 16, eval=True))
    print(f"after elastic resize + 5 more steps: eval={float(ev):.4f}")
print("done — outer momentum carried across all of the above (global-shaped)")
