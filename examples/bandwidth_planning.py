"""Cross-datacenter bandwidth planning with the paper's Table-6 simulator:
how much bandwidth does a training run need at a target compute utilization,
and what do DiLoCo's H and int8 outer compression buy?

  PYTHONPATH=src python examples/bandwidth_planning.py --params 405e9 --step-time 26
"""
import argparse

from repro.core import compute_util as cu
from repro.core import wallclock as wc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", type=float, default=10e9)
    ap.add_argument("--step-time", type=float, default=0.8)
    args = ap.parse_args()

    print(f"model: {args.params/1e9:.0f}B params, step time {args.step_time}s")
    print(f"{'method':24s}" + "".join(f"  CU={c:.0%}" for c in cu.CU_TARGETS))
    for h, label in [(1, "Data-Parallel"), (10, "DiLoCo H=10"),
                     (100, "DiLoCo H=100"), (300, "DiLoCo H=300")]:
        bw = [cu.required_bandwidth(args.params, args.step_time, c, sync_every=h) / 1e9
              for c in cu.CU_TARGETS]
        print(f"{label:24s}" + "".join(f"{b:8.1f}" for b in bw))
        if h > 1:
            bw8 = [b / 2 for b in bw]  # int8 outer-Δ vs bf16
            print(f"{label + ' +int8Δ':24s}" + "".join(f"{b:8.1f}" for b in bw8))
    print("(Gbit/s of cross-datacenter bandwidth; paper Table 6 structure)")

    print("\nIdealized end-to-end wall-clock (paper Appendix A), 20N tokens:")
    for net in (wc.LOW, wc.MEDIUM, wc.HIGH):
        dp = wc.train_time(args.params, 20 * args.params, 2**21,
                           algorithm="dp", cross_net=net)
        dl = wc.train_time(args.params, 20 * args.params, 2**21,
                           algorithm="diloco", m_replicas=4, sync_every=30,
                           cross_net=net)
        print(f"  {net.name:7s}: DP {dp['total_s']/3600:8.1f}h  "
              f"DiLoCo M=4 {dl['total_s']/3600:8.1f}h  "
              f"(speedup {dp['total_s']/dl['total_s']:.2f}x)")


if __name__ == "__main__":
    main()
