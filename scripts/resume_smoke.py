"""Resume smoke: 20 steps, checkpoint at 10, kill, resume — the final loss
must be bitwise-equal to the uninterrupted run.

Run A trains 20 steps on the default (superstep) engine, async-checkpointing
every 10, and is the uninterrupted reference.  The step-20 checkpoint is
then deleted to simulate a preemption after step 10, and run B resumes with
``--resume`` (template-free restore), training 10 -> 20.  Exit code is
non-zero on any mismatch.

  PYTHONPATH=src python scripts/resume_smoke.py
"""
import shutil
import sys
import tempfile

from repro.launch.train import build_argparser, make_run, train_loop

BASE = [
    "--arch", "tiny-t0", "--algorithm", "diloco", "--replicas", "2",
    "--sync-every", "5", "--steps", "20", "--batch-tokens", "2048",
    "--seq-len", "128", "--warmup", "2", "--eval-every", "0",
    "--log-every", "0", "--checkpoint-every", "10",
]


def run(extra):
    args = build_argparser().parse_args(BASE + extra)
    _, trainer, data, steps = make_run(args)
    _, history = train_loop(args, trainer, data, steps, quiet=True)
    return history


def main() -> int:
    with tempfile.TemporaryDirectory() as ckdir:
        full = run(["--checkpoint-dir", ckdir])
        # simulate preemption after step 10: drop everything newer
        shutil.rmtree(f"{ckdir}/step_{20:010d}")
        resumed = run(["--checkpoint-dir", ckdir, "--resume"])

    assert resumed[0]["step"] == 11, f"resume did not start at 10: {resumed[0]}"
    tail = {r["step"]: r["loss"] for r in full}
    bad = [
        (r["step"], tail[r["step"]], r["loss"])
        for r in resumed
        if r["loss"] != tail[r["step"]]
    ]
    if bad:
        for step, want, got in bad:
            print(f"step {step}: uninterrupted {want!r} != resumed {got!r}")
        print(f"FAIL: {len(bad)}/{len(resumed)} post-resume losses diverged")
        return 1
    print(
        f"resume smoke OK: steps 11..20 bitwise-equal after restart "
        f"(final loss {full[-1]['loss']:.6f})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
