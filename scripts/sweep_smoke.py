"""Sweep smoke: reduced (N x M) grid end to end, with a simulated kill.

Drives the scaling-law sweep subsystem the way CI needs it proven:

1. run the ``smoke`` grid but stop after 2 cells (a "killed" sweep);
2. re-run the full grid — the 2 completed cells MUST be skipped via the
   ledger, the remaining 4 run to completion;
3. drop one cell's ledger record while keeping its checkpoints — the
   re-run must resume that cell from its final checkpoint (zero training
   steps) and reproduce the recorded eval loss bitwise;
4. fit the ledger (``repro.launch.fit``) and sanity-check the fitted laws.

Artifacts land under ``results/`` (SWEEP_smoke.jsonl + FITS_smoke.json).
Exit code is non-zero on any violation.

  PYTHONPATH=src python scripts/sweep_smoke.py
"""
import json
import math
import os
import shutil
import sys

from repro.configs import get_sweep
from repro.launch import xla_cache
from repro.launch.fit import fit_ledger
from repro.launch.sweep import _json_safe, read_ledger, run_sweep

# persistent compilation cache: CI persists results/.xla_cache across runs
# (actions/cache), so re-runs of this drill skip XLA compilation entirely
xla_cache.enable()

LEDGER = os.path.join("results", "SWEEP_smoke.jsonl")
CKPT_ROOT = os.path.join("results", "sweep_smoke_ckpt")
FITS = os.path.join("results", "FITS_smoke.json")


def main() -> int:
    # int4 rides along so the registry-only strategy path (a new strategy
    # added with zero engine edits) is exercised by every CI run
    sweep = get_sweep("smoke").replace(modes=("dp", "diloco", "int4"))
    for p in (LEDGER, FITS):
        if os.path.exists(p):
            os.remove(p)
    shutil.rmtree(CKPT_ROOT, ignore_errors=True)

    # 1. killed sweep: only 2 of the 6 cells complete
    part = run_sweep(sweep, LEDGER, CKPT_ROOT, max_cells=2, quiet=True)
    ran = [r for r in part if not r["skipped"]]
    assert len(ran) == 2, f"expected 2 cells before the kill, ran {len(ran)}"
    assert len(read_ledger(LEDGER)) == 2

    # 2. re-run: completed cells skip via the ledger, the rest run
    full = run_sweep(sweep, LEDGER, CKPT_ROOT, quiet=True)
    skipped = [r["cell"] for r in full if r["skipped"]]
    assert skipped == [r["cell"] for r in ran], (
        f"rerun must skip exactly the pre-kill cells: {skipped}")
    done = read_ledger(LEDGER)
    assert len(done) == len(full), f"{len(done)} ledger cells != {len(full)} grid cells"

    # 3. cell-level checkpoint resume: forget one cell's record (keep its
    # checkpoints) — the re-run must restore at the final step and
    # reproduce the recorded eval bitwise, with zero training steps
    victim = full[-1]["cell"]
    old = done[victim]
    with open(LEDGER) as f:
        lines = [ln for ln in f if json.loads(ln)["cell"] != victim]
    with open(LEDGER, "w") as f:
        f.writelines(lines)
    rerun = run_sweep(sweep, LEDGER, CKPT_ROOT, quiet=True)
    new = next(r["record"] for r in rerun if r["cell"] == victim)
    assert not next(r for r in rerun if r["cell"] == victim)["skipped"]
    assert new["start_step"] == new["steps"], (
        f"cell did not resume from its final checkpoint: "
        f"start={new['start_step']} steps={new['steps']}")
    assert new["final_eval"] == old["final_eval"], (
        f"resumed eval {new['final_eval']!r} != recorded {old['final_eval']!r}")

    # 4. fit the ledger
    fits = fit_ledger(list(read_ledger(LEDGER).values()), restarts=8)
    fits["ledger"] = LEDGER
    with open(FITS, "w") as f:
        json.dump(_json_safe(fits), f, indent=1, allow_nan=False)
    laws = fits["power_laws"]
    assert laws, "no power laws fit"
    for k, v in laws.items():
        assert math.isfinite(v["A"]) and math.isfinite(v["alpha"]), (k, v)
    assert "alpha" in fits["joint"], fits["joint"]
    assert fits["headline"]["diloco_vs_dp"], "missing DiLoCo-vs-DP headline rows"

    print(f"sweep smoke OK: {len(done)} cells, kill/rerun skipped "
          f"{len(skipped)}, checkpoint-resume bitwise-equal, "
          f"{len(laws)} power laws + joint fit -> {FITS}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
