"""Chaos smoke: deterministic fault injection end to end.

Part 1 — train / corrupt / resume:

* Run A (reference): 30 steps of 2-replica DiLoCo under a fault schedule
  that kills replica 1 for rounds 2-3 (rejoining — re-seeded from the
  global params — at round 4) and makes replica 0 straggle, checkpointing
  every 10 steps.  The checkpoint writes also absorb one injected
  transient ``OSError`` via the bounded-backoff retry path.
* The newest checkpoint (step 30) is then silently corrupted
  *content-wise*: the ``.npz`` stays a perfectly valid archive, so only
  the manifest-v3 per-leaf checksums can prove the payload rotten.
* Run B resumes with ``--resume`` under the same schedule, with more
  transient I/O faults injected into the restore path.  It must detect
  the corruption, fall back to the intact step-20 checkpoint, and replay
  steps 21-30 **bitwise-equal** to run A — faults, masks, and re-seeds
  are all pure functions of ``(schedule, absolute step)``.

Part 2 — sweep containment: a 2-cell sweep runs under injected transient
ledger-append failures plus one injected cell failure; the sweep must
retry, keep going, append the contained ``"error"`` record, and still
complete every cell.

Exit code is non-zero on any violated assertion.

  PYTHONPATH=src python scripts/chaos_smoke.py
"""
import json
import os
import sys
import tempfile
import warnings

from repro.checkpoint import SCHEMA_VERSION
from repro.core import faults
from repro.launch.train import build_argparser, make_run, train_loop

# crash replica 1 for rounds [2, 4) of H=5 (steps 10-19); straggle replica 0
MASKS = "crash:replica=1,at=2,rejoin=4;straggle:replica=0,start=1,stop=3,factor=2.5"
BASE = [
    "--arch", "tiny-t0", "--algorithm", "diloco", "--replicas", "2",
    "--sync-every", "5", "--steps", "30", "--batch-tokens", "2048",
    "--seq-len", "128", "--warmup", "2", "--eval-every", "0",
    "--log-every", "0", "--checkpoint-every", "10", "--faults", MASKS,
]


def run(extra):
    args = build_argparser().parse_args(BASE + extra)
    _, trainer, data, steps = make_run(args)
    _, history = train_loop(args, trainer, data, steps, quiet=True)
    return history


def part1() -> None:
    with tempfile.TemporaryDirectory() as ckdir:
        # -- run A: uninterrupted reference, one transient save fault ------
        with faults.inject(MASKS + ";io:op=checkpoint_save,fails=1") as inj:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")  # the retry warns; that's the point
                full = run(["--checkpoint-dir", ckdir])
        assert inj.raised.get("checkpoint_save") == 1, inj.raised
        assert len(full) == 30

        manifest = json.load(
            open(os.path.join(ckdir, f"step_{30:010d}", "manifest.json")))
        assert manifest["schema"] == SCHEMA_VERSION and manifest["checksums"], (
            "expected a v3 manifest with per-leaf checksums")

        # -- silently corrupt the newest checkpoint's payload --------------
        faults.corrupt_npz(os.path.join(ckdir, f"step_{30:010d}", "state.npz"))

        # -- run B: resume under the same schedule + transient read faults -
        with faults.inject(MASKS + ";io:op=checkpoint_restore,fails=1") as inj:
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                resumed = run(["--checkpoint-dir", ckdir, "--resume"])
        assert inj.raised.get("checkpoint_restore") == 1, inj.raised
        fallback = [w for w in caught if "failed verification" in str(w.message)]
        assert fallback, "corrupt checkpoint was not detected on restore"
        assert "checksum" in str(fallback[0].message), fallback[0].message

    assert resumed[0]["step"] == 21, (
        f"expected fallback to the intact step-20 checkpoint; resume started "
        f"at {resumed[0]['step'] - 1}")
    ref = {r["step"]: r["loss"] for r in full}
    bad = [(r["step"], ref[r["step"]], r["loss"])
           for r in resumed if r["loss"] != ref[r["step"]]]
    if bad:
        for step, want, got in bad:
            print(f"step {step}: uninterrupted {want!r} != resumed {got!r}")
        raise AssertionError(
            f"{len(bad)}/{len(resumed)} post-resume losses diverged under "
            "the fault schedule")
    print(f"chaos part 1 OK: corrupt step-30 checkpoint detected via v3 "
          f"checksums, fell back to step 20, steps 21..30 bitwise-equal "
          f"(final loss {full[-1]['loss']:.6f})")


def part2() -> None:
    from repro.configs.sweeps import SweepSpec
    from repro.launch.sweep import read_ledger, run_sweep

    sweep = SweepSpec(
        name="chaos", archs=("tiny-t0",), modes=("diloco",), replicas=(2,),
        sync_every=(2,), batch_tokens=(512,), seq_len=64, steps=4,
        lrs=(1e-3, 3e-3), warmup_frac=0.25, eval_batches=2, eval_seqs=4,
    )
    with tempfile.TemporaryDirectory() as root:
        ledger = os.path.join(root, "ledger.jsonl")
        # cell 1 fails BOTH its attempts (contained); the error-record
        # append then absorbs two transient ledger faults via retry
        spec = "io:op=cell_run,fails=2;io:op=ledger_append,fails=2"
        with faults.inject(spec) as inj:
            out = run_sweep(sweep, ledger, os.path.join(root, "ckpt"),
                            quiet=True, stack=False, cell_retries=1)
        assert inj.raised == {"ledger_append": 2, "cell_run": 2}, inj.raised
        failed = [r for r in out if r.get("error")]
        assert len(failed) == 1 and failed[0]["record"] is None, (
            "expected exactly one contained cell failure")
        assert "transient cell_run" in failed[0]["error"], failed[0]
        ok = [r for r in out if r["record"]]
        assert len(ok) == 1, "the sweep should have stayed alive"
        recs = [json.loads(line) for line in open(ledger)]
        assert any("error" in r for r in recs), recs
        done = read_ledger(ledger)
        assert len(done) == 1, "an error record must not mark its cell done"

        # a later sweep picks the contained cell back up and completes it
        out2 = run_sweep(sweep, ledger, os.path.join(root, "ckpt"),
                         quiet=True, stack=False)
        assert all(r["record"] for r in out2), out2
        assert sum(r["skipped"] for r in out2) == 1, out2
        assert len(read_ledger(ledger)) == 2
    print("chaos part 2 OK: sweep survived transient ledger faults, "
          "contained a failing cell, and completed it on the next sweep")


def main() -> int:
    part1()
    part2()
    print("chaos smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
