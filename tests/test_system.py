"""End-to-end behaviour tests: the paper's algorithm on the full stack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core.diloco import make_trainer
from repro.data import SyntheticLM
from repro.models import build_model


def _mk(arch="tiny-t0", *, algo="diloco", m=1, h=5, steps=40, lr=3e-3, **dkw):
    cfg = get_config(arch)
    model = build_model(cfg)
    tcfg = TrainConfig(global_batch_tokens=8 * 128, seq_len=128, steps=steps)
    dcfg = DiLoCoConfig(
        num_replicas=m, sync_every=h, data_parallel=(algo == "dp"), **dkw
    )
    ocfg = OptimizerConfig(peak_lr=lr, warmup_steps=5)
    trainer = make_trainer(model, dcfg, ocfg, tcfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128)
    return trainer, data


def _run(trainer, data, steps, seqs=2):
    state = trainer.init_state(jax.random.PRNGKey(0))
    inner = jax.jit(trainer.inner_step)
    outer = jax.jit(trainer.outer_sync)
    losses = []
    for t in range(steps):
        batch = data.global_batch(t, trainer.M, seqs)
        state, m = inner(state, batch)
        if not trainer.dcfg.data_parallel and (t + 1) % trainer.dcfg.sync_every == 0:
            state = outer(state)
        losses.append(float(m["loss"]))
    return state, losses


def test_dp_training_reduces_loss():
    trainer, data = _mk(algo="dp", steps=40)
    _, losses = _run(trainer, data, 40, seqs=8)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_diloco_m2_training_reduces_loss_toward_floor():
    trainer, data = _mk(m=2, h=5, steps=60)
    _, losses = _run(trainer, data, 60, seqs=4)
    floor = data.entropy_floor()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
    assert np.mean(losses[-5:]) > floor - 0.1  # can't beat the source entropy


def test_fused_train_step_matches_split_loop():
    """lax.cond-fused train_step == python-scheduled inner/outer."""
    trainer, data = _mk(m=2, h=3, steps=12)
    s_fused = trainer.init_state(jax.random.PRNGKey(0))
    s_split = trainer.init_state(jax.random.PRNGKey(0))
    fused = jax.jit(trainer.train_step)
    inner = jax.jit(trainer.inner_step)
    outer = jax.jit(trainer.outer_sync)
    for t in range(7):
        batch = data.global_batch(t, 2, 2)
        s_fused, _ = fused(s_fused, batch)
        s_split, _ = inner(s_split, batch)
        if (t + 1) % 3 == 0:
            s_split = outer(s_split)
    for a, b in zip(jax.tree.leaves(s_fused), jax.tree.leaves(s_split)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)


def test_checkpoint_restart_resumes_exactly(tmp_path):
    """Fault tolerance: kill at step 10, restart, reach identical state."""
    from repro.checkpoint import Checkpointer

    trainer, data = _mk(m=2, h=4, steps=20)
    inner = jax.jit(trainer.inner_step)
    outer = jax.jit(trainer.outer_sync)

    def advance(state, t0, t1):
        for t in range(t0, t1):
            state, _ = inner(state, data.global_batch(t, 2, 2))
            if (t + 1) % 4 == 0:
                state = outer(state)
        return state

    # uninterrupted run
    ref = advance(trainer.init_state(jax.random.PRNGKey(0)), 0, 16)

    # interrupted run: checkpoint at 10, restore into a FRESH process state
    ck = Checkpointer(str(tmp_path), keep=2)
    state = advance(trainer.init_state(jax.random.PRNGKey(0)), 0, 10)
    ck.save(state, 10)
    template = trainer.init_state(jax.random.PRNGKey(42))  # different init
    restored, step = ck.restore(template)
    assert step == 10
    resumed = advance(restored, 10, 16)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)


def test_async_checkpointing(tmp_path):
    import os

    from repro.checkpoint import Checkpointer

    trainer, data = _mk(steps=4)
    state = trainer.init_state(jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save_async(state, s)
    ck.wait()
    assert ck.latest_step() == 3
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


def test_straggler_dropout_excludes_replica():
    """A straggler's delta must not influence the outer update."""
    from repro.core import elastic

    trainer, data = _mk(m=4, h=2, steps=10, outer_momentum=0.0, nesterov=False)
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, _ = jax.jit(trainer.inner_step)(state, data.global_batch(0, 4, 2))
    # corrupt replica 3's params wildly
    bad = jax.tree.map(lambda p: p.at[3].mul(100.0), state["inner_params"])
    state_bad = {**state, "inner_params": bad}
    w = elastic.participation_weights(jnp.array([True, True, True, False]))
    synced = trainer.outer_sync(state_bad, w)
    synced_ref = trainer.outer_sync(state, jnp.array([1.0, 1.0, 1.0, 0.0]))
    for a, b in zip(jax.tree.leaves(synced["global_params"]),
                    jax.tree.leaves(synced_ref["global_params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_elastic_resize_preserves_global_model():
    from repro.core import elastic

    trainer, data = _mk(m=2, h=2, steps=10)
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, _ = jax.jit(trainer.inner_step)(state, data.global_batch(0, 2, 2))
    state = trainer.outer_sync(state)
    grown = elastic.resize_replicas(trainer, state, 4)
    assert all(l.shape[0] == 4 for l in jax.tree.leaves(grown["inner_params"]))
    for leaf, g in zip(jax.tree.leaves(grown["inner_params"]),
                       jax.tree.leaves(grown["global_params"])):
        np.testing.assert_allclose(np.asarray(leaf[3]), np.asarray(g).astype(leaf.dtype))
    shrunk = elastic.resize_replicas(trainer, state, 1)
    assert all(l.shape[0] == 1 for l in jax.tree.leaves(shrunk["inner_params"]))


def test_per_step_template_free_resume_is_bitwise(tmp_path):
    """Exact resume on the per-step engine through the NEW restore path:
    no live template — structure from abstract_state(), values bitwise from
    disk, leaves device_put."""
    from repro.checkpoint import Checkpointer

    trainer, data = _mk(m=2, h=4, steps=20)

    def advance(trainer, state, t0, t1):
        inner = jax.jit(trainer.inner_step)
        for t in range(t0, t1):
            state, _ = inner(state, data.global_batch(t, 2, 2))
            if (t + 1) % 4 == 0:
                state = trainer.outer_sync(state)
        return state

    ref = advance(trainer, trainer.init_state(jax.random.PRNGKey(0)), 0, 16)

    state = advance(trainer, trainer.init_state(jax.random.PRNGKey(0)), 0, 10)
    Checkpointer(str(tmp_path), trainer=trainer).save(state, 10)

    tr2, _ = _mk(m=2, h=4, steps=20)  # fresh "process"
    restored, step = Checkpointer(str(tmp_path), trainer=tr2).restore()
    assert step == 10
    resumed = advance(tr2, restored, 10, 16)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_switching_engine_per_step_to_superstep(tmp_path):
    """A checkpoint written by the per-step loop resumes under the superstep
    engine (the state dict is engine-agnostic) and lands within engine
    tolerance of the pure per-step run."""
    from repro.checkpoint import Checkpointer
    from repro.core.superstep import SuperstepEngine

    trainer, data = _mk(m=2, h=4, steps=8)
    inner = jax.jit(trainer.inner_step)
    state = trainer.init_state(jax.random.PRNGKey(0))
    ref = trainer.init_state(jax.random.PRNGKey(0))
    for t in range(8):
        ref, _ = inner(ref, data.global_batch(t, 2, 2))
        if (t + 1) % 4 == 0:
            ref = trainer.outer_sync(ref)
        if t + 1 == 5:  # non-H-aligned switch point
            Checkpointer(str(tmp_path), trainer=trainer).save(ref, 5)

    tr2, _ = _mk(m=2, h=4, steps=8)
    restored, start = Checkpointer(str(tmp_path), trainer=tr2).restore()
    engine = SuperstepEngine(tr2, data, 2)
    out, _ = engine.run(restored, 8, start=start)
    assert int(out["step"]) == 8
    for a, b in zip(jax.tree.leaves(out["global_params"]),
                    jax.tree.leaves(ref["global_params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_train_driver_elastic_resume(tmp_path):
    """Driver-level elastic restart: checkpoint at M=2, resume the CLI run
    with --replicas 4 — restore resizes to the new M and training proceeds."""
    from repro.launch.train import build_argparser, make_run, train_loop

    base = ["--arch", "tiny-t0", "--algorithm", "diloco", "--sync-every", "4",
            "--batch-tokens", "2048", "--seq-len", "128", "--warmup", "2",
            "--eval-every", "0", "--log-every", "0",
            "--checkpoint-dir", str(tmp_path)]
    args = build_argparser().parse_args(base + ["--replicas", "2", "--steps", "4"])
    cfg, trainer, data, steps = make_run(args)
    train_loop(args, trainer, data, steps, quiet=True)  # final save at step 4

    args2 = build_argparser().parse_args(
        base + ["--replicas", "4", "--steps", "8", "--resume"])
    cfg, trainer4, data, steps = make_run(args2)
    state, history = train_loop(args2, trainer4, data, steps, quiet=True)
    assert trainer4.M == 4
    assert int(state["step"]) == 8
    assert all(l.shape[0] == 4 for l in jax.tree.leaves(state["inner_params"]))
    assert len(history) == 4  # steps 5..8 ran after the resume


def test_train_driver_resume_at_end_is_noop(tmp_path):
    """Resuming a finished run must not crash or publish a lying manifest."""
    from repro.checkpoint import Checkpointer
    from repro.launch.train import build_argparser, make_run, train_loop

    base = ["--arch", "tiny-t0", "--algorithm", "diloco", "--replicas", "2",
            "--sync-every", "4", "--steps", "4", "--batch-tokens", "2048",
            "--seq-len", "128", "--warmup", "2", "--eval-every", "0",
            "--log-every", "0", "--checkpoint-dir", str(tmp_path)]
    args = build_argparser().parse_args(base)
    _, trainer, data, steps = make_run(args)
    train_loop(args, trainer, data, steps, quiet=True)

    args2 = build_argparser().parse_args(base + ["--resume"])
    _, trainer2, data, steps = make_run(args2)
    state, history = train_loop(args2, trainer2, data, steps, quiet=True)
    assert history == []
    assert int(state["step"]) == 4
    ck = Checkpointer(str(tmp_path), trainer=trainer2)
    assert ck.latest_step() == 4  # re-saved at the state's true step


def test_train_driver_cli_smoke(tmp_path):
    from repro.launch.train import build_argparser, make_run, train_loop

    args = build_argparser().parse_args(
        ["--arch", "tiny-t0", "--algorithm", "diloco", "--replicas", "2",
         "--sync-every", "4", "--steps", "8", "--batch-tokens", "2048",
         "--seq-len", "128", "--warmup", "2", "--eval-every", "8",
         "--checkpoint-dir", str(tmp_path), "--checkpoint-every", "4"]
    )
    cfg, trainer, data, steps = make_run(args)
    state, history = train_loop(args, trainer, data, steps, quiet=True)
    assert len(history) == 8
    assert "eval_nll" in history[-1]
