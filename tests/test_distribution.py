"""Distribution tests on a small in-process mesh.

These run with the single real CPU device exposed as a 1-device mesh plus
AOT lowering checks that don't execute (lowering works for any mesh made of
the available devices — full 512-device lowering lives in launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_smoke_config
from repro.core.diloco import make_trainer
from repro.launch.mesh import make_mesh
from repro.launch.roofline import collective_traffic
from repro.models import build_model


def _trainer(arch="smollm-360m", m=1, dp=False):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    tcfg = TrainConfig(global_batch_tokens=m * 2 * 64, seq_len=64, steps=10)
    dcfg = DiLoCoConfig(num_replicas=m, sync_every=2, data_parallel=dp)
    return cfg, model, make_trainer(model, dcfg, OptimizerConfig(warmup_steps=2), tcfg)


def test_sharded_train_step_runs_on_mesh():
    """Execute (not just lower) a DiLoCo step under a 1x1x1 mesh + rules."""
    cfg, model, trainer = _trainer(m=1)
    mesh = make_mesh(1, 1, 1)
    rules = dict(sharding.DEFAULT_RULES)
    with sharding.set_mesh(mesh), sharding.use_rules(rules):
        state = trainer.init_state(jax.random.PRNGKey(0))
        batch = {
            "tokens": jnp.zeros((1, 2, 64), jnp.int32),
            "labels": jnp.zeros((1, 2, 64), jnp.int32),
        }
        in_specs = (
            sharding.tree_named(mesh, trainer.state_partition_specs()),
            sharding.tree_named(mesh, trainer.batch_partition_specs(batch)),
        )
        step = jax.jit(trainer.train_step, in_shardings=in_specs,
                       out_shardings=(in_specs[0], None))
        new_state, metrics = step(state, batch)
        assert jnp.isfinite(metrics["loss"])


def test_state_partition_specs_match_state_structure():
    for m in (1, 4):
        _, _, trainer = _trainer(m=m)
        with sharding.use_rules(dict(sharding.DEFAULT_RULES)):
            state = trainer.abstract_state()
            specs = trainer.state_partition_specs()
        assert jax.tree.structure(state) == jax.tree.structure(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
        # every leaf rank matches its spec length
        for leaf, spec in zip(
            jax.tree.leaves(state),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
        ):
            assert len(spec) <= len(leaf.shape), (spec, leaf.shape)


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-moe-16b", "mamba2-130m"])
def test_input_specs_match_partition_specs(arch):
    from repro.configs import shape_by_name

    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    for shape_name in ("train_4k", "decode_32k"):
        shape = shape_by_name(shape_name)
        shape = type(shape)(shape.name, 256, 4, shape.kind)  # reduced
        inputs = model.input_specs(shape)
        with sharding.use_rules(dict(sharding.DEFAULT_RULES)):
            specs = model.input_partition_specs(shape, inputs)
        assert set(inputs.keys()) == set(specs.keys())


def test_restore_device_puts_onto_active_mesh(tmp_path):
    """Template-free restore under an active mesh: leaves come back as
    committed device arrays sharded per state_partition_specs (not host
    numpy), so the first donating call after a restart works in place."""
    from jax.sharding import NamedSharding

    from repro.checkpoint import Checkpointer

    cfg, model, trainer = _trainer(m=2)
    mesh = make_mesh(1, 1, 1)
    rules = dict(sharding.DEFAULT_RULES)
    with sharding.set_mesh(mesh), sharding.use_rules(rules):
        assert sharding.current_mesh() is mesh
        state = trainer.init_state(jax.random.PRNGKey(0))
        ck = Checkpointer(str(tmp_path), trainer=trainer)
        ck.save(state, 1)
        restored, step = ck.restore()
        specs = jax.tree.leaves(
            trainer.state_partition_specs(), is_leaf=lambda x: isinstance(x, P)
        )
        for leaf, spec in zip(jax.tree.leaves(restored), specs):
            assert isinstance(leaf, jax.Array)
            assert isinstance(leaf.sharding, NamedSharding)
            assert leaf.sharding.spec == spec
    assert sharding.current_mesh() is None


def test_collective_parser_on_real_hlo():
    """Lower an all-reduce-containing program; parser must count its bytes."""
    mesh = make_mesh(1, 1, 1)

    def f(x):
        return jax.lax.with_sharding_constraint(x.sum(0, keepdims=True), P(None, None))

    with sharding.set_mesh(mesh):
        txt = jax.jit(lambda x: x @ x.T).lower(jnp.ones((128, 128))).compile().as_text()
    traffic = collective_traffic(txt)
    assert traffic["total_bytes"] >= 0  # no collectives on 1 device


def test_collective_parser_counts_synthetic_hlo():
    hlo = """
  %all-reduce.1 = f32[1024,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.2 = bf16[512]{0} all-gather(%y), replica_groups=[8,16]<=[128], dimensions={0}
  %tuple = (f32[4]{0}, f32[4]{0}) all-reduce(%a, %b), replica_groups={{0,1}}, to_apply=%add
"""
    t = collective_traffic(hlo)
    ar1 = 2 * 1024 * 256 * 4 * (3 / 4)
    ag = 512 * 2 * (15 / 16)
    ar2 = 2 * 8 * 4 * (1 / 2)
    assert abs(t["all-reduce"] - (ar1 + ar2)) < 1e-6
    assert abs(t["all-gather"] - ag) < 1e-6
    assert t["count"] == 3


def test_outer_sync_lowers_with_replica_allreduce():
    """On an abstract 4-replica mesh spec, the outer sync must reduce over
    the replica axis (checked via eval_shape-level lowering on 1 device)."""
    cfg, model, trainer = _trainer(m=4)
    with sharding.use_rules({"replica": None, **{k: None for k in sharding.DEFAULT_RULES}}):
        state = trainer.abstract_state(jnp.float32)
        out = jax.eval_shape(trainer.outer_sync, state)
    # global params keep their (unstacked) shape; inner params keep M axis
    for a, b in zip(jax.tree.leaves(out["global_params"]),
                    jax.tree.leaves(state["global_params"])):
        assert a.shape == b.shape
    for a in jax.tree.leaves(out["inner_params"]):
        assert a.shape[0] == 4
