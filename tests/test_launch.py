"""Launch-layer unit tests (no 512-device init needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import cells, get_config, get_smoke_config, shape_by_name
from repro.launch.costs import analytic_costs, fwd_flops_total
from repro.models import build_model, layers, transformer


def test_cells_skip_long_for_full_attention():
    assert [s.name for s in cells("qwen3-8b")] == ["train_4k", "prefill_32k", "decode_32k"]
    assert "long_500k" in [s.name for s in cells("mamba2-130m")]
    assert "long_500k" in [s.name for s in cells("jamba-1.5-large-398b")]


def test_analytic_costs_sanity():
    cfg = get_config("qwen3-8b")
    shape = shape_by_name("train_4k")
    c = analytic_costs(cfg, shape, 256)
    # train flops ~ 4x fwd ~ 8*N*D within 2x (attention adds more)
    base = 8 * cfg.active_param_count() * shape.global_batch * shape.seq_len
    assert 0.5 < c["flops_total"] / base < 2.5
    d = analytic_costs(cfg, shape_by_name("decode_32k"), 256)
    # decode flops ~ 2*N*batch
    base = 2 * cfg.active_param_count() * 128
    assert 0.5 < d["flops_total"] / base < 3.0


def test_fwd_flops_scale_with_depth():
    cfg = get_config("smollm-360m")
    a = fwd_flops_total(cfg, 1, 1024)
    b = fwd_flops_total(cfg.replace(n_layers=64), 1, 1024)
    assert b > 1.6 * a


def test_probe_cfg_shrinks_depth():
    from repro.launch.dryrun import _probe_cfg

    cfg = get_config("jamba-1.5-large-398b")
    p1 = _probe_cfg(cfg, 1)
    assert p1.n_layers == 8 and not p1.scan_layers
    p2 = _probe_cfg(cfg, 2)
    assert p2.n_layers == 16
    # deepseek-moe keeps its dense prefix layer
    cfg = get_config("deepseek-moe-16b")
    assert _probe_cfg(cfg, 2).n_layers == 3


def test_flash_kernel_path_matches_jnp_attention():
    """The TPU flash-kernel swap point is numerically equivalent."""
    cfg = get_smoke_config("qwen3-8b").replace(head_dim=32, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 128), 0, cfg.vocab_size)
    ref, _, _ = transformer.forward(params, tokens, cfg, mode="train")
    layers.USE_FLASH_KERNEL = True
    try:
        out, _, _ = transformer.forward(params, tokens, cfg, mode="train")
    finally:
        layers.USE_FLASH_KERNEL = False
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-3, rtol=3e-3
    )


def test_zero1_opt_specs_differ_from_param_specs():
    from jax.sharding import PartitionSpec as P

    from repro import sharding
    from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig
    from repro.core.diloco import make_trainer

    cfg = get_smoke_config("smollm-360m")
    model = build_model(cfg)
    trainer = make_trainer(model, DiLoCoConfig(num_replicas=1),
                           OptimizerConfig(), TrainConfig(steps=10))
    rules = dict(sharding.DEFAULT_RULES)
    rules.update({"embed": None, "opt_embed": "data"})
    with sharding.use_rules(rules):
        specs = trainer.state_partition_specs()
    p_leaves = jax.tree.leaves(specs["inner_params"], is_leaf=lambda x: isinstance(x, P))
    m_leaves = jax.tree.leaves(specs["inner_opt"]["m"], is_leaf=lambda x: isinstance(x, P))
    assert not any("data" in str(s) for s in p_leaves)   # params replicated over data
    assert any("data" in str(s) for s in m_leaves)       # moments sharded (ZeRO-1)


def test_serve_splits_prng_keys_and_reports_both_phases():
    """serve must not reuse one PRNG key for params AND prompts (the old
    bug correlated them), and must report prefill + decode throughput."""
    from repro.launch.serve import build_argparser, run_serve

    args = build_argparser().parse_args(
        ["--arch", "tiny-t0", "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    out = run_serve(args, quiet=True)
    assert out["tokens"].shape == (2, 5)  # first greedy token + 4 decoded
    for k in ("prefill_s", "prefill_tok_s", "decode_s", "decode_tok_s"):
        assert np.isfinite(out[k]) and out[k] > 0
    # key splitting: the served prompts must come from the dedicated
    # split-off key, NOT from the root key that also initialized the params
    key = jax.random.PRNGKey(args.seed)
    _, k_tokens, _, _ = jax.random.split(key, 4)
    from repro.configs import get_config

    cfg = get_config("tiny-t0")
    expect = jax.random.randint(k_tokens, (2, 8), 0, cfg.vocab_size)
    reused = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    np.testing.assert_array_equal(np.asarray(out["prompt_tokens"]), np.asarray(expect))
    assert not np.array_equal(np.asarray(out["prompt_tokens"]), np.asarray(reused))


def test_cli_list_syncs_prints_registry(capsys):
    """--list-syncs on both CLIs prints every registered strategy and
    returns without touching models or data."""
    from repro.launch import sweep, train

    train.main(["--list-syncs"])
    out = capsys.readouterr().out
    for name in ("dp", "full", "int8", "int4", "streaming"):
        assert name in out
    assert "payload B/param" in out
    sweep.main(["--list-syncs"])
    assert "int4" in capsys.readouterr().out


def test_make_run_rejects_conflicting_algorithm_and_sync():
    """--algorithm dp + an outer-opt --sync must error loudly, not silently
    run a different algorithm than the ledger records."""
    from repro.launch.train import ExperimentConfig, make_run

    with pytest.raises(ValueError, match="conflicts"):
        make_run(ExperimentConfig(arch="tiny-t0", algorithm="dp", sync="full"))
    # --sync dp with algorithm dp is the coherent spelling and works
    make_run(ExperimentConfig(arch="tiny-t0", algorithm="dp", sync="dp",
                              batch_tokens=512, seq_len=64, steps=2))


def test_collective_traffic_bf16_counting():
    from repro.launch.roofline import collective_traffic

    hlo = "%ar = f32[256]{0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%a"
    raw = collective_traffic(hlo)["total_bytes"]
    corr = collective_traffic(hlo, f32_as_bf16=True)["total_bytes"]
    assert raw == 2 * corr
