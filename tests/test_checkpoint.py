"""Checkpoint subsystem tests: manifest v2 + v1 compat, deterministic async
saves, atomicity hygiene, template-free / elastic restore, and the
cold-start AdamW semantics of fresh replicas."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import SCHEMA_VERSION, Checkpointer, config_fingerprint
from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core import elastic
from repro.core.diloco import make_trainer
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import adamw_init


def _mk(m=2, h=4, steps=20, **dkw):
    cfg = get_config("tiny-t0")
    model = build_model(cfg)
    tcfg = TrainConfig(global_batch_tokens=2 * 128, seq_len=128, steps=steps)
    dcfg = DiLoCoConfig(num_replicas=m, sync_every=h, **dkw)
    ocfg = OptimizerConfig(peak_lr=3e-3, warmup_steps=2)
    trainer = make_trainer(model, dcfg, ocfg, tcfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128)
    return trainer, data


def _advance(trainer, data, state, t0, t1, seqs=1):
    inner = jax.jit(trainer.inner_step)
    for t in range(t0, t1):
        state, _ = inner(state, data.global_batch(t, trainer.M, seqs))
        if (t + 1) % trainer.dcfg.sync_every == 0:
            state = trainer.outer_sync(state)
    return state


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# manifest schema
# ---------------------------------------------------------------------------


def test_manifest_v2_records_run_metadata(tmp_path):
    trainer, data = _mk(m=2, compression="int8")
    state = _advance(trainer, data, trainer.init_state(jax.random.PRNGKey(0)), 0, 2)
    ck = Checkpointer(str(tmp_path), trainer=trainer)
    ck.save(state, 2)
    with open(tmp_path / "step_0000000002" / "manifest.json") as f:
        man = json.load(f)
    assert man["schema"] == SCHEMA_VERSION
    assert man["step"] == 2
    assert man["num_replicas"] == 2
    assert man["sync_mode"] == "int8"
    assert man["fingerprint"] == config_fingerprint(trainer)
    assert man["dtypes"]["inner_opt/count"] == "int32"
    assert man["shapes"]["inner_opt/count"] == [2]
    assert set(man["keys"]) == set(man["dtypes"])


def test_v1_manifest_backward_compat(tmp_path):
    """Old-style dirs ({"step","keys"} manifest) restore through both the
    template path and the template-free path (M inferred from the state)."""
    trainer, data = _mk(m=2)
    state = _advance(trainer, data, trainer.init_state(jax.random.PRNGKey(0)), 0, 3)
    ck = Checkpointer(str(tmp_path), trainer=trainer)
    ck.save(state, 3)
    man_path = tmp_path / "step_0000000003" / "manifest.json"
    flat_keys = json.load(open(man_path))["keys"]
    with open(man_path, "w") as f:
        json.dump({"step": 3, "keys": flat_keys}, f)  # rewrite as v1

    template = trainer.init_state(jax.random.PRNGKey(7))
    r_tmpl, step = Checkpointer(str(tmp_path)).restore(template)
    assert step == 3
    _assert_tree_equal(r_tmpl, state)

    r_free, step = Checkpointer(str(tmp_path), trainer=trainer).restore()
    assert step == 3
    _assert_tree_equal(r_free, state)


def test_fingerprint_drift_warns_and_strict_raises(tmp_path):
    trainer, data = _mk(m=2)
    state = trainer.init_state(jax.random.PRNGKey(0))
    Checkpointer(str(tmp_path), trainer=trainer).save(state, 1)
    drifted, _ = _mk(m=2, h=8)  # sync cadence changed -> new fingerprint
    ck = Checkpointer(str(tmp_path), trainer=drifted)
    with pytest.warns(UserWarning, match="fingerprint"):
        ck.restore()
    with pytest.raises(ValueError, match="fingerprint"):
        ck.restore(strict_fingerprint=True)


def test_elastic_resize_does_not_change_fingerprint():
    tr2, _ = _mk(m=2)
    tr4, _ = _mk(m=4)
    assert config_fingerprint(tr2) == config_fingerprint(tr4)


# ---------------------------------------------------------------------------
# async pipeline
# ---------------------------------------------------------------------------


def test_save_async_wait_never_loses_checkpoint(tmp_path):
    """Hammer save_async/wait cycles: the old 1s-idle worker could exit
    between its liveness check and the enqueue, stranding the item and
    letting wait() return without writing anything."""
    trainer, data = _mk(m=1, data_parallel=True)
    state = trainer.init_state(jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=2, trainer=trainer)
    for i in range(1, 26):
        ck.save_async(state, i)
        ck.wait()
        assert ck.latest_step() == i, f"checkpoint {i} lost"
    ck.close()
    assert ck.latest_step() == 25


def test_save_async_burst_then_single_wait(tmp_path):
    trainer, _ = _mk(m=1, data_parallel=True)
    state = trainer.init_state(jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=0, trainer=trainer, max_inflight=1)
    for i in range(1, 7):  # max_inflight=1 exercises put() backpressure
        ck.save_async(state, i)
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [1, 2, 3, 4, 5, 6]
    ck.close()


def test_async_write_error_surfaces_on_wait(tmp_path):
    trainer, _ = _mk(m=1, data_parallel=True)
    state = trainer.init_state(jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), trainer=trainer)

    def boom(flat, step):
        raise RuntimeError("disk on fire")

    ck._write = boom
    ck.save_async(state, 1)
    with pytest.raises(RuntimeError, match="disk on fire"):
        ck.wait()
    # error is cleared after being raised; pipeline is usable again
    del ck._write
    ck.save_async(state, 2)
    ck.wait()
    assert ck.latest_step() == 2
    ck.close()


def test_close_is_idempotent_and_worker_restarts(tmp_path):
    trainer, _ = _mk(m=1, data_parallel=True)
    state = trainer.init_state(jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), trainer=trainer)
    ck.save_async(state, 1)
    ck.close()
    ck.close()
    assert threading.active_count() >= 1
    ck.save_async(state, 2)  # restarts the worker after close
    ck.wait()
    assert ck.latest_step() == 2
    ck.close()


# ---------------------------------------------------------------------------
# atomicity hygiene
# ---------------------------------------------------------------------------


def test_orphaned_tmp_dirs_reaped_on_init(tmp_path):
    orphan = tmp_path / "step_0000000007.tmp"
    orphan.mkdir()
    (orphan / "state.npz").write_bytes(b"garbage from a crash mid-save")
    ck = Checkpointer(str(tmp_path))
    assert not orphan.exists()
    assert ck.latest_step() is None


def test_overwrite_same_step_keeps_a_checkpoint_at_all_times(tmp_path):
    """Re-saving an existing step must move the published dir aside before
    installing the new one (never rmtree-then-replace), and leave no
    .tmp artifacts behind."""
    trainer, data = _mk(m=1, data_parallel=True)
    s1 = trainer.init_state(jax.random.PRNGKey(0))
    s2 = trainer.init_state(jax.random.PRNGKey(1))
    ck = Checkpointer(str(tmp_path), trainer=trainer)
    ck.save(s1, 3)
    ck.save(s2, 3)  # overwrite
    assert ck.latest_step() == 3
    restored, _ = Checkpointer(str(tmp_path), trainer=trainer).restore()
    _assert_tree_equal(restored, s2)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
    # a crash artifact of the move-aside protocol is reaped on init
    (tmp_path / "step_0000000003.old.tmp").mkdir()
    Checkpointer(str(tmp_path), trainer=trainer)
    assert not (tmp_path / "step_0000000003.old.tmp").exists()


def test_tmp_never_visible_as_checkpoint(tmp_path):
    trainer, _ = _mk(m=1, data_parallel=True)
    state = trainer.init_state(jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), trainer=trainer)
    ck.save(state, 1)
    (tmp_path / "step_0000000002.tmp").mkdir()  # crash artifact appears later
    assert ck.latest_step() == 1
    ck.save(state, 3)  # next save still succeeds and gc tolerates the .tmp
    assert ck.latest_step() == 3


# ---------------------------------------------------------------------------
# template-free + elastic restore
# ---------------------------------------------------------------------------


def test_template_free_restore_is_bitwise_and_donation_safe(tmp_path):
    trainer, data = _mk(m=2, compression="int8")
    state = _advance(trainer, data, trainer.init_state(jax.random.PRNGKey(0)), 0, 5)
    Checkpointer(str(tmp_path), trainer=trainer).save(state, 5)

    tr2, data = _mk(m=2, compression="int8")  # "fresh process"
    restored, step = Checkpointer(str(tmp_path), trainer=tr2).restore()
    assert step == 5
    _assert_tree_equal(restored, state)
    # leaves are committed device arrays: a donating call consumes them
    assert all(isinstance(l, jax.Array) for l in jax.tree.leaves(restored))
    out, _ = tr2.jit_inner_step()(restored, data.global_batch(5, 2, 1))
    assert jax.tree.leaves(restored["inner_params"])[0].is_deleted()
    assert not jax.tree.leaves(out["inner_params"])[0].is_deleted()


def test_template_free_restore_requires_trainer(tmp_path):
    trainer, _ = _mk(m=2)
    Checkpointer(str(tmp_path), trainer=trainer).save(
        trainer.init_state(jax.random.PRNGKey(0)), 1
    )
    with pytest.raises(ValueError, match="trainer"):
        Checkpointer(str(tmp_path)).restore()


def test_restore_sync_mode_mismatch_is_loud(tmp_path):
    """A checkpoint saved without error-feedback state cannot silently
    restore into an int8+EF trainer."""
    plain, data = _mk(m=2)
    state = _advance(plain, data, plain.init_state(jax.random.PRNGKey(0)), 0, 2)
    Checkpointer(str(tmp_path), trainer=plain).save(state, 2)
    int8, _ = _mk(m=2, compression="int8")
    with pytest.raises(KeyError, match="ef"), pytest.warns(UserWarning):
        Checkpointer(str(tmp_path), trainer=int8).restore()


@pytest.mark.parametrize("m_from,m_to", [(2, 4), (4, 2)])
def test_elastic_restore_resizes_and_trains_on(tmp_path, m_from, m_to):
    tr_a, data = _mk(m=m_from)
    state = _advance(tr_a, data, tr_a.init_state(jax.random.PRNGKey(0)), 0, 4)
    Checkpointer(str(tmp_path), trainer=tr_a).save(state, 4)

    tr_b, data = _mk(m=m_to)
    restored, step = Checkpointer(str(tmp_path), trainer=tr_b).restore()
    assert step == 4
    for leaf in jax.tree.leaves(restored["inner_params"]):
        assert leaf.shape[0] == m_to
    count = np.asarray(restored["inner_opt"]["count"])
    assert count.shape == (m_to,)
    if m_to > m_from:
        assert (count[:m_from] == 4).all() and (count[m_from:] == 0).all()
        # fresh replicas start from the global model
        for ip, gp in zip(jax.tree.leaves(restored["inner_params"]),
                          jax.tree.leaves(restored["global_params"])):
            np.testing.assert_array_equal(
                np.asarray(ip[m_from]), np.asarray(gp).astype(ip.dtype))
        for mom in jax.tree.leaves(restored["inner_opt"]["m"]):
            assert float(np.abs(np.asarray(mom[m_from:])).max()) == 0.0
    # training continues without shape errors through an outer sync
    restored = _advance(tr_b, data, restored, 4, 8)
    assert int(restored["step"]) == 8


def test_elastic_restore_grows_error_feedback(tmp_path):
    tr_a, data = _mk(m=2, compression="int8")
    state = _advance(tr_a, data, tr_a.init_state(jax.random.PRNGKey(0)), 0, 4)
    Checkpointer(str(tmp_path), trainer=tr_a).save(state, 4)
    tr_b, data = _mk(m=4, compression="int8")
    restored, _ = Checkpointer(str(tmp_path), trainer=tr_b).restore()
    for leaf in jax.tree.leaves(restored["ef"]):
        assert leaf.shape[0] == 4
        assert float(np.abs(np.asarray(leaf[2:])).max()) == 0.0  # fresh = zero residual
    restored = _advance(tr_b, data, restored, 4, 8)
    assert int(restored["step"]) == 8


def test_restore_fills_hparams_for_pre_hparams_checkpoints(tmp_path):
    """Migration: checkpoints written before the state carried the traced
    ``hparams`` leaf restore with hparams filled from the current config —
    the same values the old executables had baked in as constants."""
    trainer, data = _mk(m=2, h=4)
    state = _advance(trainer, data, trainer.init_state(jax.random.PRNGKey(0)), 0, 2)
    legacy = {k: v for k, v in state.items() if k != "hparams"}
    Checkpointer(str(tmp_path), trainer=trainer).save(legacy, 2)

    restored, step = Checkpointer(str(tmp_path), trainer=trainer).restore()
    assert step == 2
    assert restored["hparams"]["peak_lr"] == np.float32(trainer.ocfg.peak_lr)
    assert restored["hparams"]["outer_lr"] == np.float32(trainer.dcfg.outer_lr)
    assert restored["hparams"]["weight_decay"] == np.float32(trainer.weight_decay)
    _assert_tree_equal(restored["inner_params"], state["inner_params"])
    # the restored state drives the donating executables directly
    out, _ = trainer.jit_inner_step()(restored, data.global_batch(2, 2, 1))
    assert int(out["step"]) == 3


def test_restore_hparams_follow_current_config_not_checkpoint(tmp_path):
    """Relaunching with a changed lr must apply the NEW config on resume
    (the pre-traced-hparams behavior, when the new value was baked into
    fresh executables) — the checkpoint's hparams leaves must not silently
    override it.  The fingerprint warning flags the drift."""
    tr_a, data = _mk(m=2, h=4)
    state = _advance(tr_a, data, tr_a.init_state(jax.random.PRNGKey(0)), 0, 2)
    Checkpointer(str(tmp_path), trainer=tr_a).save(state, 2)

    cfg = get_config("tiny-t0")
    tr_b = make_trainer(
        build_model(cfg),
        DiLoCoConfig(num_replicas=2, sync_every=4, outer_lr=0.123),
        OptimizerConfig(peak_lr=9e-4, warmup_steps=2),
        TrainConfig(global_batch_tokens=2 * 128, seq_len=128, steps=20),
    )
    with pytest.warns(UserWarning, match="fingerprint"):
        restored, _ = Checkpointer(str(tmp_path), trainer=tr_b).restore()
    assert restored["hparams"]["peak_lr"] == np.float32(9e-4)
    assert restored["hparams"]["outer_lr"] == np.float32(0.123)


def test_elastic_restore_rejected_for_data_parallel(tmp_path):
    trainer, _ = _mk(m=1, data_parallel=True)
    Checkpointer(str(tmp_path), trainer=trainer).save(
        trainer.init_state(jax.random.PRNGKey(0)), 1
    )
    with pytest.raises(ValueError, match="data-parallel"):
        Checkpointer(str(tmp_path), trainer=trainer).restore(num_replicas=2)


# ---------------------------------------------------------------------------
# fresh-replica AdamW semantics (the resize_replicas count bug)
# ---------------------------------------------------------------------------


def test_resized_fresh_replica_first_update_is_cold_start_adamw():
    """A grown replica's first post-resize update must match a cold-start
    AdamW step from the global params: zero moments AND count=0.  With the
    old inherited count c, bias correction divides the first moment by
    1-β1^c ≈ 1 instead of 1-β1 = 0.1, under-scaling the update ~10x."""
    trainer, data = _mk(m=2, h=4)
    state = _advance(trainer, data, trainer.init_state(jax.random.PRNGKey(0)), 0, 4)
    assert int(np.asarray(state["inner_opt"]["count"])[0]) == 4

    grown = elastic.resize_replicas(trainer, state, 3)
    batch = data.global_batch(4, 3, 1)
    stepped, _ = jax.jit(trainer.inner_step)(grown, batch)

    # reference: genuine cold-start AdamW from the global params on the
    # fresh replica's own data shard at the same lr-schedule step
    gp = state["global_params"]
    shard = jax.tree.map(lambda x: x[2], batch)
    p_ref, opt_ref, _ = trainer._replica_step(
        gp, adamw_init(gp), shard, state["step"], state["hparams"])

    assert int(np.asarray(stepped["inner_opt"]["count"])[2]) == 1
    for a, b in zip(jax.tree.leaves(stepped["inner_params"]),
                    jax.tree.leaves(p_ref)):
        # vmapped vs unvmapped step: tiny fp reassociation; the inherited-
        # count bug this guards against is a ~10x update error
        np.testing.assert_allclose(
            np.asarray(a)[2], np.asarray(b), rtol=1e-3, atol=5e-5)


def test_resize_derives_old_m_from_state_not_trainer():
    """resize_replicas must work when the trainer is already configured for
    the target M (the elastic-restore call pattern)."""
    tr2, data = _mk(m=2)
    state = _advance(tr2, data, tr2.init_state(jax.random.PRNGKey(0)), 0, 2)
    tr4, _ = _mk(m=4)
    grown = elastic.resize_replicas(tr4, state, 4)  # old M read from state
    assert jax.tree.leaves(grown["inner_params"])[0].shape[0] == 4
    assert list(np.asarray(grown["inner_opt"]["count"])) == [2, 2, 0, 0]
