"""Scaling-law machinery tests — including validation against the paper's
own published Tables 4/7/10 (the fitting code must recover their fits)."""
import numpy as np
import pytest

from repro.core import scaling_laws as sl


def test_power_law_fit_recovers_synthetic():
    rng = np.random.default_rng(0)
    A, alpha = 17.5, -0.093
    n = np.geomspace(3e7, 3e9, 9)
    y = A * n ** alpha * np.exp(rng.normal(0, 1e-3, n.size))
    A2, a2 = sl.fit_power_law(n, y)
    assert abs(A2 - A) / A < 0.05 and abs(a2 - alpha) < 1e-3


def test_joint_fit_recovers_synthetic():
    rng = np.random.default_rng(0)
    A, alpha, beta = 19.0, -0.098, 0.012
    N, M = np.meshgrid(np.geomspace(3e7, 3e9, 7), [1, 2, 4, 8])
    y = A * N ** alpha * M ** beta * np.exp(rng.normal(0, 5e-4, N.shape))
    A2, a2, b2 = sl.fit_joint_power_law(N.ravel(), M.ravel(), y.ravel())
    assert abs(a2 - alpha) < 1e-3 and abs(b2 - beta) < 1e-3


def test_fit_recovers_paper_table7_from_table4():
    """Fitting the paper's published Table-4 losses must reproduce the
    paper's own Table-7 power-law coefficients."""
    for algo, losses in sl.PAPER_TABLE4_LOSS.items():
        A, alpha = sl.fit_power_law(sl.PAPER_MODEL_SIZES, losses)
        A_ref, alpha_ref = sl.PAPER_TABLE7_FITS[algo]
        assert abs(alpha - alpha_ref) < 4e-3, (algo, alpha, alpha_ref)
        assert abs(A - A_ref) / A_ref < 0.12, (algo, A, A_ref)


def test_joint_fit_recovers_paper_table10():
    n, m, y = [], [], []
    for i, mm in enumerate([1, 2, 4, 8]):
        losses = sl.PAPER_TABLE4_LOSS[f"diloco_m{mm}"]
        n.extend(sl.PAPER_MODEL_SIZES)
        m.extend([mm] * len(losses))
        y.extend(losses)
    A, alpha, beta = sl.fit_joint_power_law(n, m, y)
    A_ref, alpha_ref, beta_ref = sl.PAPER_TABLE10_JOINT["L"]
    assert abs(alpha - alpha_ref) < 4e-3
    assert abs(beta - beta_ref) < 4e-3
    assert abs(A - A_ref) / A_ref < 0.12


def test_quadratic_batch_optimum():
    b = np.array([2**i for i in range(5, 12)])
    true_opt = 2 ** 8.4
    loss = 0.01 * (np.log2(b) - np.log2(true_opt)) ** 2 + 2.5
    est = sl.quadratic_log2_optimum(b, loss)
    assert abs(np.log2(est) - np.log2(true_opt)) < 0.05


def test_parametric_forms_fit_paper_data():
    """Form 3 (paper's best) must fit the published losses well."""
    n, m, y = [], [], []
    for mm in [1, 2, 4, 8]:
        losses = sl.PAPER_TABLE4_LOSS[f"diloco_m{mm}"]
        n.extend(sl.PAPER_MODEL_SIZES)
        m.extend([mm] * len(losses))
        y.extend(losses)
    n, m, y = map(np.asarray, (n, m, y))
    holdout = n >= 2.4e9
    params, _, res = sl.fit_parametric("AN^(a+bM)+C", n, m, y,
                                       restarts=32, holdout_mask=holdout)
    assert res < 0.01  # paper reports 0.0025 on their full sweep data
    pred = sl.parametric_predict("AN^(a+bM)+C", params, n, m)
    # restarts are selected by held-out residual (paper §6.5); the train-set
    # residual is secondary — just require the same order of magnitude
    assert sl.residual(y[~holdout], pred[~holdout]) < 0.02


def test_residual_metric():
    assert sl.residual([1.0], [1.0]) == 0.0
    assert abs(sl.residual([np.e], [1.0]) - 1.0) < 1e-9
