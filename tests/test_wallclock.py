"""Appendix-A wall-clock model + Table-6 compute-utilization simulator."""
import numpy as np
import pytest

from repro.core import compute_util as cu
from repro.core import wallclock as wc


def test_allreduce_matches_formula():
    # 2N/W (1-1/R) + eps, N in bits
    t = wc.allreduce_time(1e9, 64, wc.MEDIUM)
    expect = 2 * 1e9 * 16 / 100e9 * (1 - 1 / 64) + 1e-3
    assert abs(t - expect) < 1e-12


def test_diloco_m2_inner_comm_stays_within_datacenter():
    """Cross-DC traffic must drop by ~H for DiLoCo M>=2 vs Data-Parallel."""
    kw = dict(n_params=1e9, token_budget=20e9, batch_tokens=2**20, cross_net=wc.LOW)
    dp = wc.train_time(algorithm="dp", **kw)
    dl = wc.train_time(algorithm="diloco", m_replicas=2, sync_every=30, **kw)
    assert dl["comm_s"] < dp["comm_s"] / 5
    assert dl["total_s"] < dp["total_s"]


def test_diloco_m1_outer_step_is_local():
    """M=1: one replica group — the outer step exchanges nothing across
    datacenters (the per-step all-reduce already keeps every chip in sync),
    so comm equals Data-Parallel's exactly."""
    kw = dict(n_params=1e9, token_budget=20e9, batch_tokens=2**20, cross_net=wc.HIGH)
    dp = wc.train_time(algorithm="dp", **kw)
    dl1 = wc.train_time(algorithm="diloco", m_replicas=1, sync_every=30, **kw)
    assert dl1["comm_s"] == dp["comm_s"]
    assert dl1["total_s"] == dp["total_s"]


def test_train_time_matches_hand_computed_appendix_a():
    """Regression pin against hand-computed Appendix-A values, including the
    corrected outer-sync node count: the cross-datacenter all-reduce runs
    over the M replica groups, NOT over all R chips."""
    n, budget, batch, m, h = 1e9, 20e9, 2**20, 4, 30
    out = wc.train_time(n, budget, batch, algorithm="diloco", m_replicas=m,
                        sync_every=h, cross_net=wc.MEDIUM, within_net=wc.HIGH)
    steps = budget / batch                       # 19073.48...
    r = batch // wc.TOKENS_PER_CHIP              # 128 chips
    assert out["chips"] == r == 128
    # compute: 6·N·D / (R·Q)
    comp = 6.0 * n * budget / (r * wc.CHIP_FLOPS)
    assert abs(out["compute_s"] - comp) < 1e-9 * comp
    # inner all-reduce: R/M = 32 nodes on the high net, every step
    inner = (2.0 * n * 16 / 400e9 * (1 - 1 / 32) + 1e-4) * steps
    # outer all-reduce: M = 4 nodes on the medium net, every H steps
    outer = (2.0 * n * 16 / 100e9 * (1 - 1 / 4) + 1e-3) * steps / h
    assert abs(out["comm_s"] - (inner + outer)) < 1e-9 * (inner + outer)
    # hand numbers: inner/step = 0.0776 s, outer/sync = 0.241 s
    assert abs(inner / steps - 0.0776) < 1e-12
    assert abs(outer * h / steps - 0.241) < 1e-12


def test_outer_payload_routing_hand_computed():
    """Satellite regression: outer comm billed through the sync strategy's
    payload accounting, hand-computed — int8 halves the outer bandwidth
    term, int4 quarters it, streaming sends 1/P of the payload P times per
    round (same total bytes, plus P-1 extra latency hits)."""
    from repro.core import sync

    n, budget, batch, m, h = 1e9, 20e9, 2**20, 4, 30
    steps = budget / batch
    # zero-latency cross net isolates the bandwidth term exactly
    cross = wc.Network("medium0", 100e9, 0.0)
    kw = dict(algorithm="diloco", m_replicas=m, sync_every=h,
              cross_net=cross, within_net=wc.HIGH)
    inner = (2.0 * n * 16 / 400e9 * (1 - 1 / 32) + 1e-4) * steps

    def outer_comm(strat):
        out = wc.train_time(
            n, budget, batch,
            outer_payload_bytes=strat.outer_payload_bytes(n),
            outer_syncs_per_round=strat.sync_events_per_round, **kw)
        return out["comm_s"] - inner

    full = outer_comm(sync.get("full"))
    # hand-computed: bf16 payload = 2N bytes -> 2*(2N)*8 bits on the wire
    assert abs(full - 2.0 * (2 * n) * 8 / 100e9 * (1 - 1 / m) * steps / h) < 1e-9 * full
    assert abs(outer_comm(sync.get("int8")) - full / 2) < 1e-9 * full
    assert abs(outer_comm(sync.get("int4")) - full / 4) < 1e-9 * full
    # streaming: P events of payload/P each == the full bandwidth term
    assert abs(outer_comm(sync.get("streaming", fragments=4)) - full) < 1e-9 * full
    # with latency, streaming pays the per-event latency P times
    eps = 1e-3
    lat_kw = dict(kw, cross_net=wc.Network("medium", 100e9, eps))
    full_lat = wc.train_time(
        n, budget, batch, outer_payload_bytes=2.0 * n,
        outer_syncs_per_round=1, **lat_kw)["comm_s"] - inner
    st = sync.get("streaming", fragments=4)
    st_lat = wc.train_time(
        n, budget, batch, outer_payload_bytes=st.outer_payload_bytes(n),
        outer_syncs_per_round=st.sync_events_per_round, **lat_kw)["comm_s"] - inner
    assert abs((st_lat - full_lat) - 3 * eps * steps / h) < 1e-9 * full_lat
    # defaults reproduce the paper's full-precision accounting bitwise
    a = wc.train_time(n, budget, batch, **kw)
    b = wc.train_time(n, budget, batch, outer_payload_bytes=2.0 * n,
                      outer_syncs_per_round=1, **kw)
    assert a == b


def test_bigger_batch_reduces_wallclock():
    """Horizontal scalability: doubling batch doubles chips, halves steps."""
    a = wc.train_time(n_params=1e9, token_budget=20e9, batch_tokens=2**19,
                      algorithm="diloco", m_replicas=2, cross_net=wc.LOW)
    b = wc.train_time(n_params=1e9, token_budget=20e9, batch_tokens=2**21,
                      algorithm="diloco", m_replicas=2, cross_net=wc.LOW)
    assert b["total_s"] < a["total_s"]
    assert b["chips"] == 4 * a["chips"]


def test_cu_increases_with_bandwidth_and_h():
    cu1 = cu.compute_utilization(10e9, 0.8, 10e9, sync_every=1)
    cu2 = cu.compute_utilization(10e9, 0.8, 100e9, sync_every=1)
    cu3 = cu.compute_utilization(10e9, 0.8, 10e9, sync_every=30)
    assert cu2 > cu1 and cu3 > cu1


def test_required_bandwidth_inverts_cu():
    w = cu.required_bandwidth(10e9, 0.8, 0.8, sync_every=10)
    got = cu.compute_utilization(10e9, 0.8, w, sync_every=10)
    assert abs(got - 0.8) < 1e-9


def test_table6_h_scaling_matches_paper_structure():
    """Bandwidth requirement must scale ~1/H; absolute values must land near
    the paper's published numbers (their grid snaps ~1.21x per step)."""
    rows = {(r["model"], r["method"]): r for r in cu.table6()}
    dp = rows[("Chinchilla-10B", "Data-Parallel")]["gbits"]
    h100 = rows[("Chinchilla-10B", "DiLoCo, H=100")]["gbits"]
    # paper: DP@50% = 104.8 Gbit/s for Chinchilla-10B; ours analytic 98.4
    assert abs(dp[0] - 104.8) / 104.8 < 0.25
    # paper: Llama3-405B DP@50% = 126.5; ours 122.6
    llama = rows[("Llama3-405B", "Data-Parallel")]["gbits"]
    assert abs(llama[0] - 126.5) / 126.5 < 0.1
    for a, b in zip(dp, h100):
        assert abs(a / b - 100.0) < 1e-6  # exact 1/H scaling
    # DiLoCo H=1 == Data-Parallel (paper Table 6, first two rows)
    h1 = rows[("Chinchilla-10B", "DiLoCo, H=1")]["gbits"]
    np.testing.assert_allclose(dp, h1)


def test_snap_to_grid_nearest_in_log_space():
    g = np.geomspace(1.0, 2.0 ** 8, 9)  # exact powers of two
    # just above the geometric midpoint -> snaps UP; just below -> DOWN
    mid = np.sqrt(2.0 * 4.0)
    assert cu.snap_to_grid(mid * 1.01, g) == 4.0
    assert cu.snap_to_grid(mid * 0.99, g) == 2.0
    # out-of-range clamps to the grid ends instead of silently mis-snapping
    assert cu.snap_to_grid(0.01, g) == 1.0
    assert cu.snap_to_grid(1e6, g) == 2.0 ** 8
    # vectorized
    np.testing.assert_allclose(cu.snap_to_grid([1.1, 100.0], g), [1.0, 128.0])
    with pytest.raises(ValueError):
        cu.snap_to_grid(0.0, g)


def test_snap_to_grid_matches_table6_calibration():
    """Table-6 calibration note: our analytic Llama3-405B DP@50% requirement
    (~122.6 Gbit/s) snapped to the paper's ~1.21x geometric grid must land
    on the grid point nearest the paper's published 126.5 Gbit/s."""
    rows = {(r["model"], r["method"]): r for r in cu.table6()}
    ours = rows[("Llama3-405B", "Data-Parallel")]["gbits"][0] * 1e9
    snapped = cu.snap_to_grid(ours)
    paper_snapped = cu.snap_to_grid(126.5e9)
    assert snapped == paper_snapped
    # snapping is idempotent and stays within one geometric grid step
    assert cu.snap_to_grid(snapped) == snapped
    step = (1000e9 / 0.1e9) ** (1 / 49)
    assert 1 / step < snapped / ours < step


def test_compression_halves_bandwidth():
    base = {r["method"]: r for r in cu.table6()}["DiLoCo, H=100"]["gbits"]
    comp = {r["method"]: r for r in cu.table6(compression_ratio=2.0)}["DiLoCo, H=100"]["gbits"]
    np.testing.assert_allclose(np.asarray(base) / 2, comp)
