"""Appendix-A wall-clock model + Table-6 compute-utilization simulator."""
import numpy as np

from repro.core import compute_util as cu
from repro.core import wallclock as wc


def test_allreduce_matches_formula():
    # 2N/W (1-1/R) + eps, N in bits
    t = wc.allreduce_time(1e9, 64, wc.MEDIUM)
    expect = 2 * 1e9 * 16 / 100e9 * (1 - 1 / 64) + 1e-3
    assert abs(t - expect) < 1e-12


def test_diloco_m2_inner_comm_stays_within_datacenter():
    """Cross-DC traffic must drop by ~H for DiLoCo M>=2 vs Data-Parallel."""
    kw = dict(n_params=1e9, token_budget=20e9, batch_tokens=2**20, cross_net=wc.LOW)
    dp = wc.train_time(algorithm="dp", **kw)
    dl = wc.train_time(algorithm="diloco", m_replicas=2, sync_every=30, **kw)
    assert dl["comm_s"] < dp["comm_s"] / 5
    assert dl["total_s"] < dp["total_s"]


def test_diloco_m1_adds_outer_overhead():
    kw = dict(n_params=1e9, token_budget=20e9, batch_tokens=2**20, cross_net=wc.HIGH)
    dp = wc.train_time(algorithm="dp", **kw)
    dl1 = wc.train_time(algorithm="diloco", m_replicas=1, sync_every=30, **kw)
    ratio = dl1["comm_s"] / dp["comm_s"]
    assert abs(ratio - (1 + 1 / 30)) < 1e-6


def test_bigger_batch_reduces_wallclock():
    """Horizontal scalability: doubling batch doubles chips, halves steps."""
    a = wc.train_time(n_params=1e9, token_budget=20e9, batch_tokens=2**19,
                      algorithm="diloco", m_replicas=2, cross_net=wc.LOW)
    b = wc.train_time(n_params=1e9, token_budget=20e9, batch_tokens=2**21,
                      algorithm="diloco", m_replicas=2, cross_net=wc.LOW)
    assert b["total_s"] < a["total_s"]
    assert b["chips"] == 4 * a["chips"]


def test_cu_increases_with_bandwidth_and_h():
    cu1 = cu.compute_utilization(10e9, 0.8, 10e9, sync_every=1)
    cu2 = cu.compute_utilization(10e9, 0.8, 100e9, sync_every=1)
    cu3 = cu.compute_utilization(10e9, 0.8, 10e9, sync_every=30)
    assert cu2 > cu1 and cu3 > cu1


def test_required_bandwidth_inverts_cu():
    w = cu.required_bandwidth(10e9, 0.8, 0.8, sync_every=10)
    got = cu.compute_utilization(10e9, 0.8, w, sync_every=10)
    assert abs(got - 0.8) < 1e-9


def test_table6_h_scaling_matches_paper_structure():
    """Bandwidth requirement must scale ~1/H; absolute values must land near
    the paper's published numbers (their grid snaps ~1.21x per step)."""
    rows = {(r["model"], r["method"]): r for r in cu.table6()}
    dp = rows[("Chinchilla-10B", "Data-Parallel")]["gbits"]
    h100 = rows[("Chinchilla-10B", "DiLoCo, H=100")]["gbits"]
    # paper: DP@50% = 104.8 Gbit/s for Chinchilla-10B; ours analytic 98.4
    assert abs(dp[0] - 104.8) / 104.8 < 0.25
    # paper: Llama3-405B DP@50% = 126.5; ours 122.6
    llama = rows[("Llama3-405B", "Data-Parallel")]["gbits"]
    assert abs(llama[0] - 126.5) / 126.5 < 0.1
    for a, b in zip(dp, h100):
        assert abs(a / b - 100.0) < 1e-6  # exact 1/H scaling
    # DiLoCo H=1 == Data-Parallel (paper Table 6, first two rows)
    h1 = rows[("Chinchilla-10B", "DiLoCo, H=1")]["gbits"]
    np.testing.assert_allclose(dp, h1)


def test_compression_halves_bandwidth():
    base = {r["method"]: r for r in cu.table6()}["DiLoCo, H=100"]["gbits"]
    comp = {r["method"]: r for r in cu.table6(compression_ratio=2.0)}["DiLoCo, H=100"]["gbits"]
    np.testing.assert_allclose(np.asarray(base) / 2, comp)
