"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compute_util as cu
from repro.core import outer_opt, scaling_laws as sl
from repro.core import wallclock as wc
from repro.optim import clip_by_global_norm, warmup_cosine

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# DiLoCo outer-step algebra
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    lr=st.floats(0.05, 1.0),
    mu=st.floats(0.0, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
def test_outer_step_fixed_point(lr, mu, seed):
    """Zero outer gradient + zero momentum => global model unchanged."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (16,))
    z = jnp.zeros((16,))
    new_g, new_m = outer_opt.outer_step((g,), (z,), (z,), lr=lr, mu=mu, nesterov=True)
    np.testing.assert_allclose(np.asarray(new_g[0]), np.asarray(g))
    np.testing.assert_allclose(np.asarray(new_m[0]), 0.0)


@settings(**SETTINGS)
@given(
    lr=st.floats(0.1, 1.0),
    scale=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_outer_step_is_linear_in_delta(lr, scale, seed):
    """SGD(+momentum) outer update is linear in the outer gradient."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (16,))
    d = jax.random.normal(jax.random.fold_in(key, 1), (16,)) * 0.01
    z = jnp.zeros((16,))
    g1, _ = outer_opt.outer_step((g,), (d,), (z,), lr=lr, mu=0.9, nesterov=True)
    g2, _ = outer_opt.outer_step((g,), (d * scale,), (z,), lr=lr, mu=0.9, nesterov=True)
    upd1 = np.asarray(g - g1[0])
    upd2 = np.asarray(g - g2[0])
    # float32: the update is algebraically linear; allow rounding slack
    np.testing.assert_allclose(upd2, upd1 * scale, rtol=1e-3, atol=1e-6)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 8))
def test_identical_replicas_sync_to_inner_model(seed, m):
    """If all replicas hold the same params θ, outer sync with eta=1, mu=0
    moves the global model exactly to θ (consensus is a fixed point)."""
    key = jax.random.PRNGKey(seed)
    theta = jax.random.normal(key, (8,))
    g_old = jax.random.normal(jax.random.fold_in(key, 1), (8,))
    deltas = jnp.broadcast_to(g_old - theta, (m, 8))
    z = jnp.zeros((8,))
    d_mean = deltas.mean(0)
    new_g, _ = outer_opt.outer_step((g_old,), (d_mean,), (z,), lr=1.0, mu=0.0, nesterov=False)
    np.testing.assert_allclose(np.asarray(new_g[0]), np.asarray(theta), rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 5000),
    scale=st.floats(1e-6, 1e3),
)
def test_quantization_error_bound(seed, n, scale):
    from repro.kernels.delta_quant.ops import dequantize, quantize

    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale
    q, s, meta = quantize(x)
    xr = dequantize(q, s, meta)
    # error <= half a bin of the block scale
    assert float(jnp.abs(xr - x).max()) <= float(s.max()) * 0.51 + 1e-12


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_error_feedback_telescopes(seed):
    """sum of transmitted == sum of true deltas + residual (no signal lost)."""
    from repro.core import compression

    key = jax.random.PRNGKey(seed)
    deltas = [jax.random.normal(jax.random.fold_in(key, i), (64,)) * 1e-3 for i in range(5)]
    ef = (jnp.zeros((64,)),)
    sent_total = jnp.zeros((64,))
    for d in deltas:
        sent, ef = compression.compress_tree((d,), ef)
        sent_total = sent_total + sent[0]
    true_total = sum(deltas)
    np.testing.assert_allclose(
        np.asarray(sent_total + ef[0]), np.asarray(true_total), rtol=1e-4, atol=1e-6
    )


# ---------------------------------------------------------------------------
# Schedules / clipping
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(step=st.integers(0, 2000), peak=st.floats(1e-5, 1e-1))
def test_schedule_bounds(step, peak):
    lr = float(warmup_cosine(step, peak_lr=peak, warmup=100, total=2000))
    assert 0.0 <= lr <= peak * (1 + 1e-6)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), clip=st.floats(0.1, 10.0))
def test_clip_never_increases_norm(seed, clip):
    g = {"x": jax.random.normal(jax.random.PRNGKey(seed), (32,)) * 5}
    clipped, norm = clip_by_global_norm(g, clip)
    new_norm = float(jnp.linalg.norm(clipped["x"]))
    assert new_norm <= min(float(norm), clip) * (1 + 1e-5)


# ---------------------------------------------------------------------------
# Wall-clock / CU models
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.floats(1e8, 1e12),
    w=st.floats(1e9, 1e12),
    h=st.integers(1, 300),
)
def test_cu_monotonic_in_bandwidth_and_h(n, w, h):
    a = cu.compute_utilization(n, 1.0, w, sync_every=h)
    b = cu.compute_utilization(n, 1.0, w * 2, sync_every=h)
    c = cu.compute_utilization(n, 1.0, w, sync_every=h * 2)
    assert 0 < a <= b <= 1 and a <= c <= 1


@settings(**SETTINGS)
@given(
    n=st.floats(1e8, 1e11),
    batch=st.integers(2**16, 2**24),
    h=st.integers(2, 300),
)
def test_diloco_never_communicates_more_than_dp_cross_dc(n, batch, h):
    kw = dict(n_params=n, token_budget=20 * n, batch_tokens=batch, cross_net=wc.LOW)
    dp = wc.train_time(algorithm="dp", **kw)
    dl = wc.train_time(algorithm="diloco", m_replicas=4, sync_every=h, **kw)
    assert dl["comm_s"] <= dp["comm_s"] * (1 + 1e-9)


# ---------------------------------------------------------------------------
# Scaling-law fits
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    loga=st.floats(1.0, 4.0),
    alpha=st.floats(-0.2, -0.01),
    seed=st.integers(0, 2**31 - 1),
)
def test_power_law_fit_roundtrip(loga, alpha, seed):
    rng = np.random.default_rng(seed)
    A = float(np.exp(loga))
    n = np.geomspace(1e7, 1e10, 8)
    y = A * n ** alpha * np.exp(rng.normal(0, 1e-4, 8))
    A2, a2 = sl.fit_power_law(n, y)
    assert abs(a2 - alpha) < 5e-3
    assert abs(np.log(A2) - loga) < 0.1


@settings(**SETTINGS)
@given(
    loga=st.floats(1.0, 4.0),
    alpha=st.floats(-0.2, -0.01),
    beta=st.floats(0.0, 0.05),
    seed=st.integers(0, 2**31 - 1),
)
def test_joint_power_law_fit_roundtrip(loga, alpha, beta, seed):
    """Round-trip (A, alpha, beta): f(N,M) = A·N^α·M^β with noise on the
    paper's (N, M) grid shape must be recovered by the joint fit."""
    rng = np.random.default_rng(seed)
    A = float(np.exp(loga))
    N, M = np.meshgrid(np.geomspace(1e7, 1e10, 7), [1, 2, 4, 8])
    y = A * N ** alpha * M ** beta * np.exp(rng.normal(0, 1e-4, N.shape))
    A2, a2, b2 = sl.fit_joint_power_law(N.ravel(), M.ravel(), y.ravel())
    assert abs(a2 - alpha) < 5e-3
    assert abs(b2 - beta) < 5e-3
    assert abs(np.log(A2) - loga) < 0.1
    # and the fit's own residual metric reports near-zero error
    pred = sl.predict_joint(A2, a2, b2, N.ravel(), M.ravel())
    assert sl.residual(y.ravel(), pred) < 1e-3


@settings(**SETTINGS)
@given(
    eps_scale=st.floats(1e-4, 0.05),
    c=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_residual_metric_on_table13_shaped_fits(eps_scale, c, seed):
    """res(y, ŷ) = mean |log y − log ŷ| (§6.3) on Table-13-shaped data:
    exact on constructed log-perturbations, symmetric, scale-invariant,
    and bounded by the triangle inequality under further perturbation."""
    rng = np.random.default_rng(seed)
    # the paper's published L(N, M) surface (Tables 4/13 shape: 7 N x 4 M)
    y = np.concatenate([sl.PAPER_TABLE4_LOSS[f"diloco_m{m}"] for m in (1, 2, 4, 8)])
    eps = rng.normal(0, eps_scale, y.shape)
    y_hat = y * np.exp(eps)
    res = sl.residual(y, y_hat)
    assert abs(res - np.mean(np.abs(eps))) < 1e-9
    assert abs(sl.residual(y_hat, y) - res) < 1e-12          # symmetry
    assert abs(sl.residual(c * y, c * y_hat) - res) < 1e-9   # scale invariance
    assert sl.residual(y, y) == 0.0
    # triangle inequality: perturbing ŷ further moves res by at most mean|δ|
    delta = rng.normal(0, eps_scale, y.shape)
    res2 = sl.residual(y, y_hat * np.exp(delta))
    assert res2 <= res + np.mean(np.abs(delta)) + 1e-9
