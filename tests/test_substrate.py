"""Data pipeline / optimizer / schedule / sharding-rule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding
from repro.data import SyntheticLM
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, warmup_cosine


def test_pipeline_deterministic_and_stateless():
    d1 = SyntheticLM(vocab_size=64, seq_len=32, seed=7)
    d2 = SyntheticLM(vocab_size=64, seq_len=32, seed=7)
    a = d1.batch(5, 1, 4, 2)
    b = d2.batch(5, 1, 4, 2)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = d1.batch(6, 1, 4, 2)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_labels_are_shifted_tokens():
    d = SyntheticLM(vocab_size=64, seq_len=32)
    b = d.batch(0, 0, 1, 2)
    # labels[t] continues tokens: regenerate with seq_len+0 — check shift property
    toks = np.asarray(b["tokens"])
    labs = np.asarray(b["labels"])
    np.testing.assert_array_equal(toks[:, 1:], labs[:, :-1])


def test_eval_stream_disjoint_from_train():
    d = SyntheticLM(vocab_size=64, seq_len=32)
    tr = d.batch(0, 0, 1, 2)
    ev = d.batch(0, 0, 1, 2, eval=True)
    assert not np.array_equal(np.asarray(tr["tokens"]), np.asarray(ev["tokens"]))


def test_markov_structure_is_learnable():
    """A bigram table of the stream beats the unigram entropy."""
    d = SyntheticLM(vocab_size=32, seq_len=256, n_domains=1, seed=3)
    toks = np.asarray(d.batch(0, 0, 1, 64)["tokens"]).ravel()
    uni = np.bincount(toks, minlength=32) + 1e-9
    uni = uni / uni.sum()
    h_uni = -np.sum(uni * np.log(uni))
    big = np.full((32, 32), 1e-2)
    for a, b in zip(toks[:-1], toks[1:]):
        big[a, b] += 1
    big = big / big.sum(1, keepdims=True)
    h_bi = -np.mean(np.log(big[toks[:-1], toks[1:]]))
    assert h_bi < h_uni - 0.3


def test_token_file_source(tmp_path):
    from repro.data import TokenFileSource

    path = tmp_path / "tokens.bin"
    np.arange(10_000, dtype=np.uint16).tofile(path)
    src = TokenFileSource(str(path), seq_len=64)
    b = src.batch(0, 0, 2, 4)
    assert b["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1]))
    # replica shards are disjoint and deterministic
    b0 = src.batch(3, 0, 2, 4)
    b1 = src.batch(3, 1, 2, 4)
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))
    again = src.batch(3, 0, 2, 4)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]), np.asarray(again["tokens"]))


def test_warmup_cosine_schedule():
    lr0 = warmup_cosine(0, peak_lr=1.0, warmup=100, total=1000)
    lr_peak = warmup_cosine(100, peak_lr=1.0, warmup=100, total=1000)
    lr_end = warmup_cosine(1000, peak_lr=1.0, warmup=100, total=1000)
    assert float(lr0) == 0.0
    assert abs(float(lr_peak) - 1.0) < 1e-6
    assert abs(float(lr_end) - 0.05) < 1e-6  # paper: decay to 5% of peak


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}  # norm = 10
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0) < 1e-5
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-5


def test_adamw_decoupled_weight_decay():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.zeros((4,))}
    st = adamw_init(p)
    p2, _ = adamw_update(p, g, st, lr=0.1, weight_decay=0.5)
    # zero grad -> pure decay: p - lr*wd*p
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0 - 0.1 * 0.5, rtol=1e-6)


def test_sharding_rules_context():
    from jax.sharding import PartitionSpec as P

    with sharding.use_rules({"batch": "data", "heads": "model"}):
        assert sharding.spec("batch", None, "heads") == P("data", None, "model")
    assert sharding.current_rules() == {}


def test_rules_for_uneven_arch_overrides():
    from repro.launch.mesh import rules_for

    r = rules_for("granite-moe-3b-a800m", "train")
    assert r["experts"] is None and r["expert_ff"] == "model"
    r = rules_for("smollm-360m", "train")
    assert r["heads"] is None
    r = rules_for("jamba-1.5-large-398b", "decode", global_batch=1)
    assert r["batch"] is None and r["kv_seq"] == ("data", "model")
