"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED config of the same family and runs one forward
AND one DiLoCo train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    ASSIGNED_ARCHS,
    DiLoCoConfig,
    OptimizerConfig,
    TrainConfig,
    get_config,
    get_smoke_config,
)
from repro.core.diloco import make_trainer
from repro.models import build_model


def _batch(cfg, b=2, t=32, key=None):
    key = key or jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (b, t), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(key, (b, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (b, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), (arch, float(loss))
    assert 0 < float(loss) < 3 * np.log(cfg.vocab_size)
    assert jnp.isfinite(metrics["nll"])


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_diloco_train_step(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe:
        cfg = cfg.replace(moe_group_size=64)
    model = build_model(cfg)
    tcfg = TrainConfig(global_batch_tokens=2 * 2 * 64, seq_len=64, steps=10)
    trainer = make_trainer(
        model, DiLoCoConfig(num_replicas=2, sync_every=1),
        OptimizerConfig(peak_lr=1e-3, warmup_steps=2), tcfg,
    )
    state = trainer.init_state(jax.random.PRNGKey(0))
    per = _batch(cfg, b=2, t=64)
    batch = jax.tree.map(lambda x: jnp.stack([x, x]), per)
    new_state, metrics = jax.jit(trainer.train_step)(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    # params actually changed and stayed finite
    moved = False
    for a, b in zip(jax.tree.leaves(state["inner_params"]),
                    jax.tree.leaves(new_state["inner_params"])):
        assert np.isfinite(np.asarray(b)).all(), arch
        moved |= not np.array_equal(np.asarray(a), np.asarray(b))
    assert moved, arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_published_size(arch):
    """Analytic param counts land on the published model sizes."""
    published = {
        "deepseek-moe-16b": 16.4e9, "granite-moe-3b-a800m": 3.3e9,
        "jamba-1.5-large-398b": 398e9, "llava-next-mistral-7b": 7.2e9,
        "gemma-2b": 2.5e9, "qwen3-8b": 8.2e9, "smollm-360m": 0.36e9,
        "deepseek-67b": 67.4e9, "seamless-m4t-medium": 0.6e9,
        "mamba2-130m": 0.13e9,
    }
    n = get_config(arch).param_count()
    assert abs(n - published[arch]) / published[arch] < 0.08, (arch, n / 1e9)


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-130m", "seamless-m4t-medium"])
def test_decode_matches_full_forward(arch):
    """Prefill+decode agrees with a full forward pass (serving correctness)."""
    cfg = get_smoke_config(arch)
    if cfg.ssm_state:
        cfg = cfg.replace(ssm_chunk=4)
    if cfg.moe:
        cfg = cfg.replace(capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, t = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(0), (b, t), 0, cfg.vocab_size)
    cache = model.init_cache(b, 64)
    if cfg.is_encdec:
        from repro.models import encdec

        frames = jax.random.normal(jax.random.PRNGKey(2), (b, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
        plog, c2 = model.prefill(params, {"frames": frames, "tokens": tokens}, cache)
        nxt = jnp.argmax(plog[:, -1], -1)[:, None]
        dlog, _ = model.decode_step(params, {"tokens": nxt, "enc_out": c2["enc_out"]}, c2["kv"], jnp.asarray(t))
        enc_out = encdec.encode(params, frames, cfg)
        ref, _ = encdec.decode(params, jnp.concatenate([tokens, nxt], 1), enc_out, cfg, mode="train")
    else:
        from repro.models import transformer

        plog, cache = model.prefill(params, {"tokens": tokens}, cache)
        nxt = jnp.argmax(plog[:, -1], -1)[:, None]
        dlog, _ = model.decode_step(params, {"tokens": nxt}, cache, jnp.asarray(t))
        ref, _, _ = transformer.forward(params, jnp.concatenate([tokens, nxt], 1), cfg, mode="train")
    np.testing.assert_allclose(
        np.asarray(dlog[:, 0]), np.asarray(ref[:, -1]), atol=2e-3
    )


def test_hybrid_layer_plan():
    cfg = get_config("jamba-1.5-large-398b")
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    assert kinds.count("attn") == 9  # 1:7 attn:mamba over 72 layers
    mlps = [cfg.mlp_kind(i) for i in range(cfg.n_layers)]
    assert mlps.count("moe") == 36  # MoE every other layer


def test_moe_capacity_overflow_reported():
    cfg = get_smoke_config("deepseek-moe-16b").replace(capacity_factor=0.5, moe_group_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, b=2, t=64)
    _, metrics = model.loss_fn(params, batch)
    assert float(metrics["moe_overflow"]) > 0  # tight capacity must drop tokens
