"""SyncStrategy registry + protocol tests: lookup errors, collisions,
spec parsing, manifest-tag round-trips, static-signature identity, the
legacy-flag deprecation shim (config equivalence), payload accounting, and
end-to-end registration of a custom strategy through the public API."""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, config_fingerprint
from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core import sync
from repro.core.diloco import make_trainer, static_signature
from repro.core.sync_int4 import QMAX, int4_block_quantize
from repro.core.superstep import SuperstepEngine
from repro.data import SyntheticLM
from repro.models import build_model

BUILTINS = ("dp", "full", "int8", "int4", "streaming")


def _trainer(m=2, h=4, **dkw):
    cfg = get_config("tiny-t0")
    model = build_model(cfg)
    tcfg = TrainConfig(global_batch_tokens=2 * 128, seq_len=128, steps=20)
    return make_trainer(
        model, DiLoCoConfig(num_replicas=m, sync_every=h, **dkw),
        OptimizerConfig(peak_lr=3e-3, warmup_steps=2), tcfg,
    ), SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_builtins_registered():
    assert set(BUILTINS) <= set(sync.names())


def test_unknown_strategy_lists_known_names():
    with pytest.raises(KeyError) as e:
        sync.get("gossip")
    msg = str(e.value)
    for name in BUILTINS:
        assert name in msg


def test_registration_collision_rejected():
    with pytest.raises(ValueError, match="already registered"):
        @sync.register("int8")
        class Impostor(sync.SyncStrategy):
            pass
    # the original registration is untouched
    assert type(sync.get("int8")).__name__ == "Int8Sync"


def test_parse_spec_options_and_errors():
    s = sync.parse_spec("streaming:fragments=4")
    assert s.fragments == 4
    assert s.spec() == "streaming:fragments=4"
    assert sync.parse_spec("int8:error_feedback=false").error_feedback is False
    assert sync.parse_spec("full").spec() == "full"
    with pytest.raises(ValueError, match="key=value"):
        sync.parse_spec("streaming:fragments")
    with pytest.raises(ValueError, match="valid options"):
        sync.parse_spec("full:bogus=1")
    with pytest.raises(KeyError, match="unknown sync strategy"):
        sync.parse_spec("nope:x=1")


# ---------------------------------------------------------------------------
# manifest tags
# ---------------------------------------------------------------------------


def test_manifest_tag_roundtrip_for_every_registered_strategy(tmp_path):
    """Every registered strategy's checkpoint manifest tag maps back to the
    same strategy class (``"none"`` stays aliased to full-precision)."""
    for name in sync.names():
        strat = sync.get(name)
        m = 2 if strat.uses_outer_opt else 1
        trainer, _ = _trainer(m=m, sync=name)
        ckpt_dir = tmp_path / name
        Checkpointer(str(ckpt_dir), trainer=trainer).save(
            trainer.init_state(jax.random.PRNGKey(0)), 1)
        with open(ckpt_dir / "step_0000000001" / "manifest.json") as f:
            man = json.load(f)
        assert man["sync_mode"] == strat.tag
        assert sync.from_tag(man["sync_mode"]) is type(strat), name
    # legacy alias: pre-strategy manifests record "none" for full precision
    assert sync.from_tag("none").__name__ == "FullSync"
    with pytest.raises(KeyError, match="known tags"):
        sync.from_tag("martian")


# ---------------------------------------------------------------------------
# static signature
# ---------------------------------------------------------------------------


def test_static_signature_differs_across_strategies_not_hparams():
    sigs = {}
    for name in ("full", "int8", "int4", "streaming"):
        trainer, _ = _trainer(m=2, sync=name)
        sigs[name] = static_signature(trainer)
    assert len(set(sigs.values())) == len(sigs)  # every strategy distinct
    # hparam-only changes (lr / outer-lr / momentum) do NOT change it
    cfg = get_config("tiny-t0")
    model = build_model(cfg)
    tcfg = TrainConfig(global_batch_tokens=2 * 128, seq_len=128, steps=20)
    a = make_trainer(model, DiLoCoConfig(num_replicas=2, sync_every=4, sync="int4"),
                     OptimizerConfig(peak_lr=3e-3, warmup_steps=2), tcfg)
    b = make_trainer(model, DiLoCoConfig(num_replicas=2, sync_every=4, sync="int4",
                                         outer_lr=0.123, outer_momentum=0.5),
                     OptimizerConfig(peak_lr=9e-4, warmup_steps=2), tcfg)
    assert static_signature(a) == static_signature(b)
    # strategy OPTIONS are structural: they must change the signature
    c = make_trainer(model, DiLoCoConfig(num_replicas=2, sync_every=4,
                                         sync="int4:error_feedback=false"),
                     OptimizerConfig(peak_lr=3e-3, warmup_steps=2), tcfg)
    assert static_signature(a) != static_signature(c)


# ---------------------------------------------------------------------------
# legacy-flag deprecation shim (config equivalence)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("legacy_kw,spec", [
    (dict(compression="int8"), "int8"),
    (dict(streaming_fragments=2), "streaming:fragments=2"),
])
def test_legacy_flags_resolve_to_same_strategy_with_deprecation(legacy_kw, spec):
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = sync.resolve(DiLoCoConfig(num_replicas=2, sync_every=4, **legacy_kw))
    new = sync.resolve(DiLoCoConfig(num_replicas=2, sync_every=4, sync=spec))
    assert legacy == new  # same class, same options (dataclass equality)
    assert legacy.static_signature() == new.static_signature()


def test_legacy_and_spec_configs_share_config_fingerprint():
    """Existing checkpoints must keep restoring without a drift warning:
    the fingerprint canonicalizes both spellings to the same digest."""
    for legacy_kw, spec_kw in [
        (dict(data_parallel=True), dict(sync="dp")),
        (dict(), dict(sync="full")),
        (dict(compression="int8"), dict(sync="int8")),
        (dict(streaming_fragments=2), dict(sync="streaming:fragments=2")),
    ]:
        m = 1 if legacy_kw.get("data_parallel") else 2
        tr_legacy, _ = _trainer(m=m, **legacy_kw)
        tr_spec, _ = _trainer(m=m, **spec_kw)
        assert config_fingerprint(tr_legacy) == config_fingerprint(tr_spec), spec_kw


def test_dp_and_full_resolve_without_deprecation_warning(recwarn):
    sync.resolve(DiLoCoConfig())
    sync.resolve(DiLoCoConfig(num_replicas=1, data_parallel=True))
    assert not [w for w in recwarn if w.category is DeprecationWarning]


def test_sync_spec_is_exclusive_with_legacy_flags():
    with pytest.raises(ValueError, match="exclusive"):
        DiLoCoConfig(sync="int8", compression="int8")
    with pytest.raises(ValueError, match="exclusive"):
        DiLoCoConfig(sync="full", data_parallel=True)
    with pytest.raises(ValueError, match="exclusive"):
        DiLoCoConfig(sync="streaming:fragments=2", streaming_fragments=2)


def test_strategy_validation_fails_fast():
    # spec-based streaming inherits the P <= H rule
    with pytest.raises(ValueError, match="sync_every"):
        _trainer(m=2, h=4, sync="streaming:fragments=8")
    # dp through the spec path keeps the M == 1 contract
    with pytest.raises(ValueError, match="M=1"):
        _trainer(m=2, sync="dp")


# ---------------------------------------------------------------------------
# payload accounting
# ---------------------------------------------------------------------------


def test_outer_payload_bytes_and_compression_ratios():
    n = 1e9
    assert sync.get("dp").outer_payload_bytes(n) == 0.0
    assert sync.get("full").outer_payload_bytes(n) == 2.0 * n     # bf16
    assert sync.get("int8").outer_payload_bytes(n) == 1.0 * n     # 1 B/param
    assert sync.get("int4").outer_payload_bytes(n) == 0.5 * n     # 4 bit/param
    st = sync.get("streaming", fragments=4)
    assert st.outer_payload_bytes(n) == 2.0 * n / 4               # per event
    assert st.sync_events_per_round == 4                          # P events
    # full-round ratios vs bf16: streaming moves the same total bytes
    ratios = {name: sync.get(name).compression_ratio for name in BUILTINS}
    assert ratios["full"] == ratios["dp"] == ratios["streaming"] == 1.0
    assert ratios["int8"] == 2.0 and ratios["int4"] == 4.0


# ---------------------------------------------------------------------------
# int4 quantizer
# ---------------------------------------------------------------------------


def test_int4_block_quantize_levels_and_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (300, 200)) * 0.3
    deq = int4_block_quantize(x)
    # block scale = amax/QMAX; every dequantized value is a multiple of a
    # block scale and the roundoff is bounded by scale/2 <= amax/(2*QMAX)
    amax = float(jnp.abs(x).max())
    assert float(jnp.abs(deq).max()) <= amax + 1e-6
    assert float(jnp.abs(deq - x).max()) <= amax / (2 * QMAX) + 1e-6
    # far coarser than int8 — it really is 4-bit (few distinct levels/block)
    assert len(np.unique(np.asarray(deq))) <= (2 * QMAX + 1) * (300 * 200 // (256 * 128) + 1)
    # exact zero stays exact (EF residuals start at zero)
    assert float(jnp.abs(int4_block_quantize(jnp.zeros((64, 64)))).max()) == 0.0


def test_int4_error_feedback_telescopes():
    """With EF, the quantization bias must not accumulate: the sum of
    transmitted deltas + final residual telescopes to the sum of the true
    deltas (same invariant the int8 path holds)."""
    from repro.core import compression

    key = jax.random.PRNGKey(1)
    true = [jax.random.normal(jax.random.fold_in(key, i), (257, 130)) * 0.1
            for i in range(4)]
    sent_total, ef = 0.0, None
    for d in true:
        (sent,), ef = compression.compress_tree(
            (d,), ef, quantize=int4_block_quantize)
        sent_total = sent_total + sent
    resid = jax.tree.leaves(ef)[0]
    np.testing.assert_allclose(
        np.asarray(sent_total + resid), np.asarray(sum(true)),
        rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# custom strategy through the public API (the README worked example)
# ---------------------------------------------------------------------------


def test_custom_strategy_registers_and_trains_end_to_end(tmp_path):
    """A user-defined strategy — registered with zero edits anywhere —
    trains on the compiled superstep engine, stamps its tag into the
    checkpoint manifest, and resolves back from it."""
    import dataclasses as dc

    @sync.register("sign")
    @dc.dataclass(frozen=True)
    class SignSync(sync.SyncStrategy):
        """signSGD-style outer sync: transmit sign(Δ) * mean|Δ| (1 bit/param
        + one fp32 scale per tensor)."""

        def apply(self, trainer, state, weights=None):
            delta = jax.tree.map(
                lambda g, p: g.astype(jnp.float32)
                - jnp.mean(p, axis=0, dtype=jnp.float32),
                state["global_params"], state["inner_params"],
            )
            delta = jax.tree.map(
                lambda d: jnp.sign(d) * jnp.mean(jnp.abs(d)), delta)
            return sync.outer_update(trainer, state, delta)

        def outer_payload_bytes(self, n_params):
            return n_params / 8.0  # 1 bit/param

    try:
        assert "sign" in sync.names()
        trainer, data = _trainer(m=2, h=2, sync="sign")
        assert trainer.sync_mode == "sign"
        assert trainer.sync.compression_ratio == 16.0
        state = trainer.init_state(jax.random.PRNGKey(0))
        engine = SuperstepEngine(trainer, data, 1)
        state, mets = engine.run(state, 4)
        assert np.isfinite(mets["loss"]).all()
        assert int(state["step"]) == 4
        ck = Checkpointer(str(tmp_path), trainer=trainer)
        ck.save(state, 4)
        with open(tmp_path / "step_0000000004" / "manifest.json") as f:
            assert json.load(f)["sync_mode"] == "sign"
        assert sync.from_tag("sign") is SignSync
        restored, step = Checkpointer(str(tmp_path), trainer=trainer).restore()
        assert step == 4
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        sync.unregister("sign")
