"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (bh, bkv, sq, skv, d, causal, dtype)
    (4, 4, 256, 256, 64, True, jnp.float32),
    (8, 2, 256, 256, 128, True, jnp.float32),
    (4, 2, 128, 384, 64, False, jnp.float32),
    (2, 1, 256, 256, 32, True, jnp.float32),
    (4, 4, 128, 128, 64, True, jnp.bfloat16),
    (2, 2, 384, 128, 256, False, jnp.float32),   # gemma-style head_dim 256
]


@pytest.mark.parametrize("bh,bkv,sq,skv,d,causal,dtype", FLASH_CASES)
def test_flash_attention_fwd(bh, bkv, sq, skv, d, causal, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (bh, sq, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (bkv, skv, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (bkv, skv, d), dtype)
    o = flash_attention(q, k, v, causal)
    o_ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("bh,bkv,sq,skv,d,causal,dtype", FLASH_CASES[:4])
def test_flash_attention_grads(bh, bkv, sq, skv, d, causal, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (bh, sq, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (bkv, skv, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (bkv, skv, d), dtype)
    w = jnp.cos(jnp.arange(d))

    g1 = jax.grad(lambda *a: (flash_attention(*a, causal) * w).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (attention_ref(*a, causal=causal) * w).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# fused adamw
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1000,), (64, 64), (3, 17, 29), (256 * 128 + 1,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_adamw(shape, dtype):
    from repro.kernels.fused_adamw.ops import fused_adamw
    from repro.kernels.fused_adamw.ref import adamw_ref

    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, shape, dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), shape, dtype)
    m = jax.random.normal(jax.random.PRNGKey(2), shape, jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), shape, jnp.float32)) * 0.01
    kw = dict(lr=3e-4, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.02, bc1=0.271, bc2=0.039)
    out_k = fused_adamw(p, g, m, v, **kw)
    out_r = adamw_ref(p, g, m, v, **kw)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5, rtol=1e-5
        )


# ---------------------------------------------------------------------------
# outer nesterov
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m_replicas", [1, 2, 8])
@pytest.mark.parametrize("shape", [(513,), (32, 33)])
def test_outer_nesterov(m_replicas, shape):
    from repro.kernels.outer_nesterov.ops import outer_nesterov
    from repro.kernels.outer_nesterov.ref import outer_ref

    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, shape)
    d = jax.random.normal(jax.random.PRNGKey(1), (m_replicas, *shape)) * 0.01
    m = jax.random.normal(jax.random.PRNGKey(2), shape) * 0.001
    a = outer_nesterov(g, d, m, lr=0.7, mu=0.9)
    b = outer_ref(g, d, m, lr=0.7, mu=0.9, nesterov=True)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# delta quant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(100,), (777, 33), (256 * 128,), (5, 7, 11)])
def test_delta_quant_roundtrip(shape):
    from repro.kernels.delta_quant.ops import dequantize, quantize

    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 0.01
    q, s, meta = quantize(x)
    xr = dequantize(q, s, meta)
    assert q.dtype == jnp.int8
    # error bounded by half a quantization bin of the per-block scale
    assert float(jnp.abs(xr - x).max()) <= float(s.max()) * 0.51


def test_delta_quant_matches_ref_blocks():
    from repro.kernels.delta_quant.ops import _to_lanes, quantize
    from repro.kernels.delta_quant.ref import quantize_ref

    x = jax.random.normal(jax.random.PRNGKey(0), (1000, 64))
    q, s, _ = quantize(x)
    x2, _ = _to_lanes(x)
    qr, sr = quantize_ref(x2)
    # fp rounding ties at .5 may flip the odd element by one code point
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert (diff <= 1).all()
    assert (diff != 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s[:, 0]), np.asarray(sr[:, 0]), rtol=1e-6)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (b, l, h, p, n, g, chunk)
    (2, 64, 8, 16, 32, 1, 16),
    (1, 128, 8, 32, 16, 2, 32),
    (2, 96, 16, 16, 64, 1, 32),
]


@pytest.mark.parametrize("b,l,h,p,n,g,chunk", SSD_CASES)
def test_ssd_scan(b, l, h, p, n, g, chunk):
    from repro.kernels.ssd_scan.ops import ssd_chunk_scan
    from repro.kernels.ssd_scan.ref import ssd_ref

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, l, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.PRNGKey(3), (b, l, g, n)) * 0.3
    C = jax.random.normal(jax.random.PRNGKey(4), (b, l, g, n)) * 0.3
    y1, s1 = ssd_chunk_scan(x, dt, A, B, C, chunk=chunk)
    y2, s2 = ssd_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4, rtol=1e-4)


def test_ssd_kernel_matches_recurrent_reference():
    """Oracle-of-the-oracle: chunked == naive token-by-token recurrence."""
    b, l, h, p, n = 1, 32, 4, 8, 16
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, l, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.PRNGKey(3), (b, l, 1, n)) * 0.3
    C = jax.random.normal(jax.random.PRNGKey(4), (b, l, 1, n)) * 0.3

    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        dA = jnp.exp(dt[:, t] * A)                        # (b, h)
        upd = dt[:, t][..., None, None] * x[:, t][..., None] * B[:, t, 0][:, None, None, :]
        state = state * dA[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", state, C[:, t, 0]))
    y_naive = jnp.stack(ys, axis=1)

    from repro.kernels.ssd_scan.ops import ssd_chunk_scan

    y_k, s_k = ssd_chunk_scan(x, dt, A, B, C, chunk=8)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_naive), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(state), atol=1e-4, rtol=1e-4)
