"""Algorithm-level unit tests for DiLoCo (paper Algorithm 1 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core import compression, outer_opt, streaming
from repro.core.diloco import make_trainer
from repro.data import SyntheticLM
from repro.models import build_model


def _trainer(m=1, h=1, **kw):
    cfg = get_config("tiny-t0")
    model = build_model(cfg)
    tcfg = TrainConfig(global_batch_tokens=4 * 128, seq_len=128, steps=50)
    dkw = dict(num_replicas=m, sync_every=h)
    dkw.update(kw)
    trainer = make_trainer(model, DiLoCoConfig(**dkw), OptimizerConfig(peak_lr=1e-3, warmup_steps=5), tcfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128)
    return trainer, data


def test_diloco_m1_h1_eta1_equals_data_parallel():
    """Paper §2.2: with eta=1, no momentum, H=1, DiLoCo M=1 IS Data-Parallel."""
    dl, data = _trainer(m=1, h=1, outer_lr=1.0, outer_momentum=0.0, nesterov=False)
    dp, _ = _trainer(m=1, data_parallel=True)
    s_dl = dl.init_state(jax.random.PRNGKey(0))
    s_dp = dp.init_state(jax.random.PRNGKey(0))
    for t in range(4):
        b = data.global_batch(t, 1, 2)
        s_dl, _ = jax.jit(dl.train_step)(s_dl, b)
        s_dp, _ = jax.jit(dp.train_step)(s_dp, b)
    for a, b in zip(jax.tree.leaves(s_dl["inner_params"]), jax.tree.leaves(s_dp["inner_params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_outer_gradient_definition():
    """Δ = θ_global - mean_m θ_m; with eta=1, mu=0: θ' = mean_m θ_m."""
    trainer, data = _trainer(m=4, h=1, outer_lr=1.0, outer_momentum=0.0, nesterov=False)
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, _ = jax.jit(trainer.inner_step)(state, data.global_batch(0, 4, 1))
    synced = trainer.outer_sync(state)
    for g, p in zip(jax.tree.leaves(synced["global_params"]),
                    jax.tree.leaves(state["inner_params"])):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(p.astype(jnp.float32).mean(0)), atol=1e-6
        )


def test_outer_nesterov_math():
    g = jnp.ones((4, 4))
    d = jnp.full((4, 4), 0.1)
    m = jnp.full((4, 4), 0.2)
    new_g, new_m = outer_opt.outer_step((g,), (d,), (m,), lr=0.5, mu=0.9, nesterov=True)
    expect_m = 0.9 * 0.2 + 0.1
    expect_step = 0.1 + 0.9 * expect_m
    np.testing.assert_allclose(np.asarray(new_m[0]), expect_m, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_g[0]), 1.0 - 0.5 * expect_step, rtol=1e-6)


def test_inner_state_persists_across_sync():
    """Paper §2.1: replicas keep inner optimizer state across rounds."""
    trainer, data = _trainer(m=2, h=2)
    state = trainer.init_state(jax.random.PRNGKey(0))
    inner = jax.jit(trainer.inner_step)
    for t in range(2):
        state, _ = inner(state, data.global_batch(t, 2, 1))
    m_before = jax.tree.leaves(state["inner_opt"]["m"])[0].copy()
    state = trainer.outer_sync(state)
    m_after = jax.tree.leaves(state["inner_opt"]["m"])[0]
    np.testing.assert_array_equal(np.asarray(m_before), np.asarray(m_after))
    assert int(state["inner_opt"]["count"][0]) == 2


def test_replicas_see_disjoint_data():
    data = SyntheticLM(vocab_size=64, seq_len=32)
    b = data.global_batch(0, 4, 2)
    toks = np.asarray(b["tokens"])
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(toks[i], toks[j])


def test_int8_compression_error_feedback_reduces_bias():
    key = jax.random.PRNGKey(0)
    delta = jax.random.normal(key, (256,)) * 1e-3
    # one-shot quantization error
    sent, ef = compression.compress_tree((delta,))
    err1 = float(jnp.abs(sent[0] - delta).mean())
    # with error feedback, the residual is carried, not lost
    total_sent = jnp.zeros_like(delta)
    e = (jnp.zeros_like(delta),)
    for _ in range(8):
        sent, e = compression.compress_tree((delta,), e)
        total_sent += sent[0]
    avg = total_sent / 8
    err8 = float(jnp.abs(avg - delta).mean())
    assert err8 < err1 * 0.6  # EF averages the quantization noise away
    assert err1 > 0  # quantization is actually lossy


def test_compressed_diloco_trains():
    trainer, data = _trainer(m=2, h=2, compression="int8")
    state = trainer.init_state(jax.random.PRNGKey(0))
    assert "ef" in state
    losses = []
    inner = jax.jit(trainer.inner_step)
    outer = jax.jit(trainer.outer_sync)
    for t in range(20):
        state, m = inner(state, data.global_batch(t, 2, 4))
        if (t + 1) % 2 == 0:
            state = outer(state)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_streaming_fragments_cover_all_leaves():
    trainer, data = _trainer(m=2, h=4, streaming_fragments=3)
    state = trainer.init_state(jax.random.PRNGKey(0))
    n_leaves = len(jax.tree.leaves(state["global_params"]))
    assign = streaming.fragment_assignment(state["global_params"], 3)
    assert sorted(set(assign)) == [0, 1, 2]
    assert len(assign) == n_leaves
    # every fragment is due exactly once per H-step window
    due = [f for s in range(1, 5) for f in streaming.fragments_due(s, 3, 4)]
    assert sorted(due) == [0, 1, 2]


def test_streaming_equals_full_sync_when_one_fragment():
    """P=1 streaming == classic DiLoCo outer sync."""
    tr_s, data = _trainer(m=2, h=2, streaming_fragments=1)
    tr_c, _ = _trainer(m=2, h=2)
    s1 = tr_s.init_state(jax.random.PRNGKey(0))
    s2 = tr_c.init_state(jax.random.PRNGKey(0))
    inner = jax.jit(tr_s.inner_step)
    for t in range(4):
        b = data.global_batch(t, 2, 2)
        s1, _ = inner(s1, b)
        s2, _ = inner(s2, b)
        for f in streaming.fragments_due(t + 1, 1, 2):
            s1 = streaming.outer_sync_fragment(tr_s, s1, f)
        if (t + 1) % 2 == 0:
            s2 = tr_c.outer_sync(s2)
    for a, b in zip(jax.tree.leaves(s1["global_params"]), jax.tree.leaves(s2["global_params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_eval_uses_global_model():
    trainer, data = _trainer(m=2, h=100)  # never synced
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, _ = jax.jit(trainer.inner_step)(state, data.global_batch(0, 2, 1))
    p = trainer.eval_params(state)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(state["global_params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
