"""Async DiLoCo + microbatch accumulation + auto rule validation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core.async_diloco import AsyncDiLoCo, simulate
from repro.core.diloco import make_trainer
from repro.data import SyntheticLM
from repro.models import build_model


def _mk(m=2, h=4, microbatches=1, steps=40):
    cfg = get_config("tiny-t0")
    model = build_model(cfg)
    tcfg = TrainConfig(global_batch_tokens=m * 2 * 128, seq_len=128, steps=steps,
                       microbatches=microbatches)
    trainer = make_trainer(model, DiLoCoConfig(num_replicas=m, sync_every=h),
                           OptimizerConfig(peak_lr=3e-3, warmup_steps=5), tcfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128)
    return trainer, data


# ---------------------------------------------------------------------------
# async DiLoCo
# ---------------------------------------------------------------------------


def test_async_equals_sync_when_simultaneous():
    """Equal speeds + discount 1.0 + arrivals in replica order == classic
    DiLoCo up to update ORDER: with momentum the sequential applications
    differ, so test the M=1 case where it must match exactly."""
    trainer, data = _mk(m=1, h=2)
    sync_state = trainer.init_state(jax.random.PRNGKey(0))
    a = AsyncDiLoCo(trainer, staleness_discount=1.0)
    async_state = a.init_state(jax.random.PRNGKey(0))

    inner = jax.jit(trainer.inner_step)
    outer = jax.jit(trainer.outer_sync)
    a_inner = jax.jit(a.replica_inner_step, static_argnums=1)
    a_arrive = jax.jit(a.arrive, static_argnums=1)

    for t in range(4):
        b = data.batch(t, 0, 1, 2)
        sync_state, _ = inner(sync_state, jax.tree.map(lambda x: x[None], b))
        async_state = a_inner(async_state, 0, b)
        if (t + 1) % 2 == 0:
            sync_state = outer(sync_state)
            async_state = a_arrive(async_state, 0)
    for x, y in zip(jax.tree.leaves(sync_state["global_params"]),
                    jax.tree.leaves(async_state["global_params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


def test_async_with_stragglers_still_learns():
    trainer, data = _mk(m=4, h=4, steps=60)
    a = AsyncDiLoCo(trainer, staleness_discount=0.5)
    # replica 3 runs at 1/2 speed -> stale arrivals
    _, losses = simulate(a, data, steps=12, h=4, speeds=[2, 2, 2, 1])
    assert losses[-1] < losses[0] - 0.1
    assert np.isfinite(losses).all()


def test_staleness_discount_downweights():
    # momentum off: otherwise a zero delta still moves θ via the momentum tail
    cfg = get_config("tiny-t0")
    model = build_model(cfg)
    trainer = make_trainer(
        model, DiLoCoConfig(num_replicas=2, sync_every=1, outer_momentum=0.0, nesterov=False),
        OptimizerConfig(peak_lr=3e-3, warmup_steps=5),
        TrainConfig(global_batch_tokens=512, seq_len=128, steps=40),
    )
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128)
    a = AsyncDiLoCo(trainer, staleness_discount=0.0)  # stale updates ignored
    st = a.init_state(jax.random.PRNGKey(0))
    st = a.replica_inner_step(st, 0, data.batch(0, 0, 2, 2))
    st = a.replica_inner_step(st, 1, data.batch(0, 1, 2, 2))
    st = a.arrive(st, 0)                 # fresh: applies
    g_after_first = jax.tree.leaves(st["global_params"])[0].copy()
    st = a.arrive(st, 1)                 # staleness 1, discount 0 -> no-op delta
    g_after_second = jax.tree.leaves(st["global_params"])[0]
    np.testing.assert_allclose(np.asarray(g_after_first), np.asarray(g_after_second),
                               atol=1e-7)


# ---------------------------------------------------------------------------
# microbatch gradient accumulation
# ---------------------------------------------------------------------------


def test_microbatch_accumulation_matches_full_batch():
    tr_full, data = _mk(m=1, h=100, microbatches=1)
    tr_mb, _ = _mk(m=1, h=100, microbatches=2)
    s1 = tr_full.init_state(jax.random.PRNGKey(0))
    s2 = tr_mb.init_state(jax.random.PRNGKey(0))
    batch = jax.tree.map(lambda x: x[None], data.batch(0, 0, 1, 4))
    s1, m1 = jax.jit(tr_full.inner_step)(s1, batch)
    s2, m2 = jax.jit(tr_mb.inner_step)(s2, batch)
    # mean-of-microbatch-grads == full-batch grad (loss is a token mean over
    # equal-sized microbatches)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    for a, b in zip(jax.tree.leaves(s1["inner_params"]), jax.tree.leaves(s2["inner_params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# auto rule validation
# ---------------------------------------------------------------------------


def test_auto_validate_rules_drops_indivisible():
    from repro.launch.mesh import auto_validate_rules
    from repro.sharding import DEFAULT_RULES

    model = build_model(get_config("granite-moe-3b-a800m"))
    rules = dict(DEFAULT_RULES)  # naive: experts->model (40 % 16 != 0)
    out, dropped = auto_validate_rules(model, rules, {"data": 16, "model": 16})
    assert "experts" in dropped and out["experts"] is None
    # a clean model keeps its rules
    model2 = build_model(get_config("qwen3-8b"))
    out2, dropped2 = auto_validate_rules(model2, dict(DEFAULT_RULES), {"data": 16, "model": 16})
    assert "heads" not in dropped2 and out2["heads"] == "model"
