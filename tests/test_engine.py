"""Superstep engine tests: per-step equivalence, donation, data prefetch,
cell batching, cross-trainer executable sharing, and the streaming
fragment schedule/config regressions."""
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core import jitcache, streaming
from repro.core.cellbatch import CellBatchEngine
from repro.core.diloco import make_trainer, static_signature
from repro.core.superstep import RoundPrefetcher, SuperstepEngine, device_batch_fn
from repro.data import SyntheticLM, TokenFileSource


def _trainer(m=2, h=4, peak_lr=1e-3, data_seed=1234, **kw):
    cfg = get_config("tiny-t0")
    from repro.models import build_model

    model = build_model(cfg)
    tcfg = TrainConfig(global_batch_tokens=4 * 128, seq_len=128, steps=50)
    dkw = dict(num_replicas=m, sync_every=h)
    dkw.update(kw)
    trainer = make_trainer(
        model, DiLoCoConfig(**dkw), OptimizerConfig(peak_lr=peak_lr, warmup_steps=5), tcfg
    )
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128, seed=data_seed)
    return trainer, data


def _per_step_reference(trainer, data, steps, seqs):
    """The classic inner_step/outer_sync loop (no donation: state stays
    inspectable), including mid-round fragment syncs for fragment-wise
    strategies."""
    strat = trainer.sync
    state = trainer.init_state(jax.random.PRNGKey(0))
    inner = jax.jit(trainer.inner_step)
    outer = jax.jit(trainer.outer_sync)
    losses = []
    for t in range(steps):
        state, met = inner(state, data.global_batch(t, trainer.M, seqs))
        losses.append(float(met["loss"]))
        if strat.uses_outer_opt:
            if strat.num_fragments:
                for f in strat.fragments_due(t + 1, trainer.dcfg.sync_every):
                    state = streaming.outer_sync_fragment(trainer, state, f)
            elif (t + 1) % trainer.dcfg.sync_every == 0:
                state = outer(state)
    return state, losses


MODES = {
    "dp": dict(m=1, data_parallel=True),
    "diloco": dict(m=2),
    "int8": dict(m=2, compression="int8"),
    "streaming": dict(m=2, streaming_fragments=2),
    # the registry-only strategy (repro.core.sync_int4): proves a strategy
    # added with zero engine edits rides every engine/resume path
    "int4": dict(m=2, sync="int4"),
}

# legacy-flag spelling -> equivalent sync-strategy spec, for the pre/post
# redesign equivalence matrix (old configs and strategy specs must resolve
# to the same strategy and produce bitwise-identical trajectories)
LEGACY_SPECS = {
    "dp": (dict(m=1, data_parallel=True), dict(m=1, sync="dp")),
    "diloco": (dict(m=2), dict(m=2, sync="full")),
    "int8": (dict(m=2, compression="int8"), dict(m=2, sync="int8")),
    "streaming": (dict(m=2, streaming_fragments=2),
                  dict(m=2, sync="streaming:fragments=2")),
}


@pytest.mark.parametrize("mode", sorted(MODES))
def test_superstep_matches_per_step_loop(mode):
    """The compiled round must reproduce the per-step loop across an H
    boundary (6 steps, H=4: one full round + a partial tail round)."""
    kw = dict(MODES[mode])
    m = kw.pop("m")
    steps, h, seqs = 6, 4, 2
    tr_ref, data = _trainer(m=m, h=h, **kw)
    state_ref, losses_ref = _per_step_reference(tr_ref, data, steps, seqs)

    tr_eng, _ = _trainer(m=m, h=h, **kw)
    engine = SuperstepEngine(tr_eng, data, seqs)
    state = tr_eng.init_state(jax.random.PRNGKey(0))
    state, mets = engine.run(state, steps)

    np.testing.assert_allclose(mets["loss"], losses_ref, rtol=2e-5, atol=1e-6)
    assert int(state["step"]) == int(state_ref["step"]) == steps
    for key in state_ref:
        for a, b in zip(jax.tree.leaves(state[key]), jax.tree.leaves(state_ref[key])):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5,
                err_msg=f"mode={mode} state[{key!r}]",
            )


def test_device_batch_fn_matches_host_batches():
    """On-device generation folds the step counter exactly like the host."""
    data = SyntheticLM(vocab_size=64, seq_len=32)
    fn = jax.jit(device_batch_fn(data, num_replicas=3, batch_seqs=2))
    for step in (0, 1, 17):
        dev = fn(jnp.int32(step))
        host = data.global_batch(step, 3, 2)
        np.testing.assert_array_equal(np.asarray(dev["tokens"]), np.asarray(host["tokens"]))
        np.testing.assert_array_equal(np.asarray(dev["labels"]), np.asarray(host["labels"]))


def test_token_file_source_prefetch_matches_per_step(tmp_path):
    """File-backed data takes the prefetcher path and still matches the
    per-step loop exactly."""
    rng = np.random.default_rng(0)
    path = tmp_path / "tokens.bin"
    rng.integers(0, 250, size=6000).astype(np.uint16).tofile(path)
    data = TokenFileSource(str(path), seq_len=128)

    tr_ref, _ = _trainer(m=2, h=2)
    state_ref, losses_ref = _per_step_reference(tr_ref, data, 4, 2)

    tr_eng, _ = _trainer(m=2, h=2)
    engine = SuperstepEngine(tr_eng, data, 2)
    assert not engine._on_device_data  # prefetcher path
    state = tr_eng.init_state(jax.random.PRNGKey(0))
    state, mets = engine.run(state, 4)
    np.testing.assert_allclose(mets["loss"], losses_ref, rtol=2e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(state["global_params"]),
                    jax.tree.leaves(state_ref["global_params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_token_file_eval_is_held_out(tmp_path):
    """eval=True batches must come from the reserved tail of the file."""
    path = tmp_path / "t.bin"
    np.arange(0, 40 * 4 + 1, dtype=np.uint16).tofile(path)
    data = TokenFileSource(str(path), seq_len=4, eval_frac=0.25)
    assert data._n_seqs == 30 and data._n_eval == 10
    train_b = data.batch(0, 0, 1, 30)
    eval_b = data.batch(0, 0, 1, 10, eval=True)
    # file is arange: token value == position; pools must not overlap
    assert int(np.max(train_b["tokens"])) < 30 * 4
    assert int(np.min(eval_b["tokens"])) >= 30 * 4


# ---------------------------------------------------------------------------
# legacy-flag configs vs sync-strategy specs: bitwise-identical trajectories
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", sorted(LEGACY_SPECS))
def test_legacy_flags_and_sync_spec_trajectories_bitwise_equal(mode):
    """Acceptance: every legacy sync mode produces bitwise-identical
    training trajectories whether configured through the old flag triple
    (data_parallel / compression / streaming_fragments) or the strategy
    spec (``DiLoCoConfig(sync=...)``) — on the per-step loop, the compiled
    superstep engine, and (via a mixed legacy+spec stack) the cell-batched
    engine."""
    legacy_kw, spec_kw = LEGACY_SPECS[mode]
    steps, h, seqs = 6, 4, 2

    def mk(kw):
        kw = dict(kw)
        return _trainer(m=kw.pop("m"), h=h, **kw)

    tr_legacy, data = mk(legacy_kw)
    tr_spec, _ = mk(spec_kw)
    # same strategy identity -> same manifest tag, same executables
    assert tr_legacy.sync_mode == tr_spec.sync_mode
    assert type(tr_legacy.sync) is type(tr_spec.sync)
    assert static_signature(tr_legacy) == static_signature(tr_spec)

    # per-step loop
    st_l, losses_l = _per_step_reference(tr_legacy, data, steps, seqs)
    st_s, losses_s = _per_step_reference(tr_spec, data, steps, seqs)
    assert losses_l == losses_s
    for a, b in zip(jax.tree.leaves(st_l), jax.tree.leaves(st_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # superstep engine
    out_l = tr_legacy.init_state(jax.random.PRNGKey(0))
    out_l, mets_l = SuperstepEngine(tr_legacy, data, seqs).run(out_l, steps)
    out_s = tr_spec.init_state(jax.random.PRNGKey(0))
    out_s, mets_s = SuperstepEngine(tr_spec, data, seqs).run(out_s, steps)
    np.testing.assert_array_equal(mets_l["loss"], mets_s["loss"])
    for a, b in zip(jax.tree.leaves(out_l), jax.tree.leaves(out_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # cell-batched engine: a legacy-config cell and a spec-config cell
    # stack into ONE executable (equal static signatures) and stay bitwise
    # equal to each other and to the sequential superstep run
    tr_l2, _ = mk(legacy_kw)
    tr_s2, _ = mk(spec_kw)
    d2 = SyntheticLM(vocab_size=data.vocab_size, seq_len=128, seed=1234)
    engine = CellBatchEngine([tr_l2, tr_s2], [d2, d2], seqs)
    states = engine.init_states([0, 0])
    states, mets = engine.run(states, steps)
    np.testing.assert_array_equal(mets["loss"][0], mets["loss"][1])
    np.testing.assert_array_equal(mets["loss"][0], mets_l["loss"])
    c0, c1 = engine.unstack(states)
    for a, b in zip(jax.tree.leaves(c0), jax.tree.leaves(c1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engines_agree_on_round_boundary_eligibility():
    """Satellite regression: the window/H-boundary predicate once lived as
    a copied flag expression in superstep.py AND cellbatch.py; both engines
    must now consult the same strategy capability
    (``SyncStrategy.pins_round_boundary``) for EVERY registered strategy —
    a boundary-crossing window raises on both engines or on neither."""
    from repro.core import sync as sync_lib

    for name in sync_lib.names():
        m = 1 if not sync_lib.get(name).uses_outer_opt else 2
        tr_a, data = _trainer(m=m, h=4, sync=name)
        tr_b, _ = _trainer(m=m, h=4, sync=name)
        sup = SuperstepEngine(tr_a, data, 1)
        cell = CellBatchEngine([tr_b], [data], 1)
        pinned = tr_a.sync.pins_round_boundary
        assert tr_b.sync.pins_round_boundary == pinned
        verdicts = []
        for engine, trainer in ((sup, tr_a), (cell, tr_b)):
            state = trainer.init_state(jax.random.PRNGKey(0))
            if engine is cell:
                from repro.core.cellbatch import stack_trees

                state = stack_trees([state])
            try:
                # crosses the interior H boundary at step 4
                engine.run_round(state, start=2, length=4)
                verdicts.append(False)
            except ValueError as e:
                assert "outer-sync boundary" in str(e)
                verdicts.append(True)
        assert verdicts == [pinned, pinned], (name, verdicts)


# ---------------------------------------------------------------------------
# cell batching: K stacked cells == K sequential runs, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", sorted(MODES))
def test_cellbatch_matches_superstep_per_cell(mode):
    """A stacked K-cell round must reproduce each cell's sequential
    superstep run bitwise — final state AND per-step losses — for every
    sync mode, with cells differing in inner lr, outer lr, and data seed
    (the traced hyperparameter axes)."""
    kw = dict(MODES[mode])
    m = kw.pop("m")
    steps, h, seqs = 6, 4, 2
    variants = [
        dict(peak_lr=1e-3, data_seed=11, outer_lr=0.7, seed=0),
        dict(peak_lr=2e-3, data_seed=22, outer_lr=0.5, seed=1),
    ]

    refs = []
    for v in variants:
        vkw = dict(kw)
        if not vkw.get("data_parallel"):
            vkw["outer_lr"] = v["outer_lr"]
        tr, data = _trainer(m=m, h=h, peak_lr=v["peak_lr"],
                            data_seed=v["data_seed"], **vkw)
        state = tr.init_state(jax.random.PRNGKey(v["seed"]))
        state, mets = SuperstepEngine(tr, data, seqs).run(state, steps)
        refs.append((state, mets))

    trainers, datas = [], []
    for v in variants:
        vkw = dict(kw)
        if not vkw.get("data_parallel"):
            vkw["outer_lr"] = v["outer_lr"]
        tr, data = _trainer(m=m, h=h, peak_lr=v["peak_lr"],
                            data_seed=v["data_seed"], **vkw)
        trainers.append(tr)
        datas.append(data)
    engine = CellBatchEngine(trainers, datas, seqs)
    states = engine.init_states([v["seed"] for v in variants])
    states, mets = engine.run(states, steps)
    assert mets["loss"].shape == (2, steps)

    for k, (ref_state, ref_mets) in enumerate(refs):
        np.testing.assert_array_equal(mets["loss"][k], ref_mets["loss"])
        cell = engine.unstack(states)[k]
        assert int(cell["step"]) == int(ref_state["step"]) == steps
        for key in ref_state:
            for a, b in zip(jax.tree.leaves(cell[key]),
                            jax.tree.leaves(ref_state[key])):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"mode={mode} cell={k} state[{key!r}]",
                )


def test_cellbatch_rejects_mixed_shapes_and_file_data(tmp_path):
    tr1, d1 = _trainer(m=2, h=4)
    tr2, d2 = _trainer(m=2, h=8)  # different H -> different signature
    with pytest.raises(ValueError, match="static signature"):
        CellBatchEngine([tr1, tr2], [d1, d2], 1)
    path = tmp_path / "t.bin"
    np.arange(0, 2000, dtype=np.uint16).tofile(path)
    tfs = TokenFileSource(str(path), seq_len=128)
    tr3, _ = _trainer(m=2, h=4)
    with pytest.raises(ValueError, match="SyntheticLM"):
        CellBatchEngine([tr1, tr3], [d1, tfs], 1)


# ---------------------------------------------------------------------------
# cross-trainer executable sharing (jitcache)
# ---------------------------------------------------------------------------


def test_trainers_differing_only_in_hparams_share_executables():
    """lr / outer-lr / momentum are traced through the state's hparams
    leaf, so same-shape trainers share one compiled entry point; a
    structural difference (H) must NOT share."""
    tr_a, data = _trainer(m=2, h=4, peak_lr=1e-3)
    tr_b, _ = _trainer(m=2, h=4, peak_lr=3e-3, outer_lr=0.4)
    tr_c, _ = _trainer(m=2, h=8)
    assert static_signature(tr_a) == static_signature(tr_b)
    assert static_signature(tr_a) != static_signature(tr_c)
    assert tr_a.jit_inner_step() is tr_b.jit_inner_step()
    assert tr_a.jit_inner_step() is not tr_c.jit_inner_step()

    eng_a = SuperstepEngine(tr_a, data, 2)
    eng_b = SuperstepEngine(tr_b, SyntheticLM(
        vocab_size=data.vocab_size, seq_len=128, seed=77), 2)
    assert eng_a._round_fn(4, True) is eng_b._round_fn(4, True)
    # ...and the shared executable still gives each trainer its own lr
    sa = tr_a.init_state(jax.random.PRNGKey(0))
    sb = tr_b.init_state(jax.random.PRNGKey(0))
    assert float(sa["hparams"]["peak_lr"]) != float(sb["hparams"]["peak_lr"])
    fn = tr_a.jit_inner_step(donate=False)
    batch = data.global_batch(0, 2, 2)
    _, met_a = fn(sa, batch)
    _, met_b = fn(sb, batch)
    assert float(met_a["lr"]) != float(met_b["lr"])


def test_sharing_can_be_disabled():
    with jitcache.sharing(False):
        tr_a, _ = _trainer(m=2, h=4)
        tr_b, _ = _trainer(m=2, h=4)
        assert tr_a.jit_inner_step() is not tr_b.jit_inner_step()


def test_round_prefetcher_double_buffers():
    data = SyntheticLM(vocab_size=32, seq_len=16)
    pf = RoundPrefetcher(data, num_replicas=2, batch_seqs=1)
    xs = pf.get(0, 3)
    assert xs["tokens"].shape == (3, 2, 1, 16)
    assert (0 + 3, 3) in pf._pending  # next round already scheduled
    xs2 = pf.get(3, 3)
    ref = data.global_batch(4, 2, 1)
    np.testing.assert_array_equal(np.asarray(xs2["tokens"][1]), np.asarray(ref["tokens"]))


def test_round_prefetcher_close_cancels_inflight_build(monkeypatch):
    """close() must stop an already-running _build before its device_put:
    a speculative batch must never land on device after close (it would
    stay pinned for the engine's lifetime)."""
    started, release = threading.Event(), threading.Event()

    class SlowSource:
        def global_batch(self, step, m, bs):
            started.set()
            release.wait(timeout=10)
            return {"tokens": np.zeros((m, bs, 4), np.int32)}

    puts = []
    real_put = jax.device_put
    monkeypatch.setattr(jax, "device_put", lambda x: (puts.append(1), real_put(x))[1])

    pf = RoundPrefetcher(SlowSource(), num_replicas=1, batch_seqs=1)
    pf.schedule(0, 3)   # starts running, blocks in global_batch
    pf.schedule(3, 3)   # queued behind it
    assert started.wait(10)
    queued = pf._pending[(3, 3)]
    fut = pf._pending[(0, 3)]
    pf.close()
    release.set()
    assert fut.result(timeout=10) is None   # in-flight build bailed
    assert queued.cancelled()               # queued build never started
    assert puts == []                       # nothing materialized on device
    with pytest.raises(RuntimeError, match="closed"):
        pf.get(0, 3)


def test_round_prefetcher_surfaces_worker_errors():
    """A data source raising on the worker thread must re-raise at the next
    get() — for the matched round AND for a parked mispredicted build —
    never be silently swallowed with a discarded future."""

    class BoomSource:
        def global_batch(self, step, m, bs):
            raise RuntimeError("data source exploded")

    pf = RoundPrefetcher(BoomSource(), num_replicas=1, batch_seqs=1)
    pf.schedule(0, 2)
    with pytest.raises(RuntimeError, match="exploded"):
        pf.get(0, 2)  # the background failure re-raises in the caller
    pf.close()

    # one-shot failure on a speculative build whose round is then never
    # fetched under that key: the parked error still surfaces
    inner = SyntheticLM(vocab_size=32, seq_len=16)

    class OneShotBoom:
        def __init__(self):
            self.boomed = False

        def global_batch(self, step, m, bs):
            if step >= 2 and not self.boomed:
                self.boomed = True
                raise RuntimeError("transient data failure")
            return inner.global_batch(step, m, bs)

    pf = RoundPrefetcher(OneShotBoom(), num_replicas=1, batch_seqs=1)
    assert pf.get(0, 2) is not None      # schedules (2, 2), which will fail
    pf._pending[(2, 2)].result()         # worker finishes and parks the error
    with pytest.raises(RuntimeError, match="transient data failure"):
        pf.get(0, 2)
    # the parked error is consumed: the next fetch recovers (rebuilds)
    assert pf.get(2, 2, next_length=0) is not None
    pf.close()


def test_donated_entry_points_consume_state():
    """jit_inner_step/jit_outer_sync donate: the old state must be dead."""
    trainer, data = _trainer(m=2, h=2)
    state = trainer.init_state(jax.random.PRNGKey(0))
    new_state, _ = trainer.jit_inner_step()(state, data.global_batch(0, 2, 1))
    assert jax.tree.leaves(new_state["inner_params"])[0].is_deleted() is False
    assert jax.tree.leaves(state["inner_params"])[0].is_deleted()
    state2, _ = trainer.jit_inner_step()(new_state, data.global_batch(1, 2, 1))
    synced = trainer.jit_outer_sync()(state2)
    assert jax.tree.leaves(state2["global_params"])[0].is_deleted()
    assert not jax.tree.leaves(synced["global_params"])[0].is_deleted()


def test_superstep_run_round_consumes_state():
    trainer, data = _trainer(m=2, h=2)
    engine = SuperstepEngine(trainer, data, 1)
    state = trainer.init_state(jax.random.PRNGKey(0))
    new_state, mets = engine.run_round(state, 0)
    assert mets["loss"].shape == (2,)
    assert jax.tree.leaves(state["inner_params"])[0].is_deleted()
    assert not jax.tree.leaves(new_state["inner_params"])[0].is_deleted()


def test_superstep_rejects_bad_configs():
    # streaming + compression is rejected at config construction (both
    # engines and the checkpoint manifest's sync_mode must agree)
    with pytest.raises(ValueError, match="compression"):
        _trainer(m=2, h=4, streaming_fragments=2, compression="int8",
                 error_feedback=False)
    # chunk length is free for DP but pinned to sync_every for DiLoCo
    tr_dp, data = _trainer(m=1, h=4, data_parallel=True)
    SuperstepEngine(tr_dp, data, 1, chunk=6)
    tr_dl, data = _trainer(m=2, h=4)
    with pytest.raises(ValueError):
        SuperstepEngine(tr_dl, data, 1, chunk=6)


def test_run_round_rejects_window_crossing_sync_boundary():
    """A window spanning an interior H boundary would silently skip that
    boundary's outer sync — the engine must refuse it."""
    trainer, data = _trainer(m=2, h=4)
    engine = SuperstepEngine(trainer, data, 1)
    state = trainer.init_state(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="outer-sync boundary"):
        engine.run_round(state, start=2, length=4)  # crosses step 4
    state, _ = engine.run_round(state, start=2, length=2)  # up to the boundary
    state, _ = engine.run_round(state, start=4, length=3)  # tail, no boundary


# ---------------------------------------------------------------------------
# bitwise resume equivalence (checkpoint at a NON-H-aligned step)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", sorted(MODES))
def test_superstep_resume_is_bitwise_exact(mode, tmp_path):
    """train(8) == train(5) + checkpoint + restore + train(3) — bitwise —
    under the superstep engine, for every sync mode.  The restore step (5)
    deliberately does not land on the H=4 boundary, so the resumed engine
    must split its first round at the boundary (engine.round_bounds) and the
    prefetch cursor / on-device datagen must re-align to the absolute step."""
    from repro.checkpoint import Checkpointer

    kw = dict(MODES[mode])
    m = kw.pop("m")
    steps, h, seqs, k = 8, 4, 1, 5

    tr_a, data = _trainer(m=m, h=h, **kw)
    ref = tr_a.init_state(jax.random.PRNGKey(0))
    ref, _ = SuperstepEngine(tr_a, data, seqs).run(ref, steps)

    tr_b, _ = _trainer(m=m, h=h, **kw)
    st = tr_b.init_state(jax.random.PRNGKey(0))
    st, _ = SuperstepEngine(tr_b, data, seqs).run(st, k)
    Checkpointer(str(tmp_path), trainer=tr_b).save(st, k)

    tr_c, _ = _trainer(m=m, h=h, **kw)  # fresh "process"
    restored, start = Checkpointer(str(tmp_path), trainer=tr_c).restore()
    assert start == k
    out, _ = SuperstepEngine(tr_c, data, seqs).run(restored, steps, start=start)

    assert int(out["step"]) == int(ref["step"]) == steps
    for key in ref:
        for a, b in zip(jax.tree.leaves(out[key]), jax.tree.leaves(ref[key])):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"mode={mode} state[{key!r}] not bitwise equal",
            )


def test_token_file_resume_realigns_prefetch_cursor(tmp_path):
    """File-backed resume: the RoundPrefetcher is keyed on the absolute
    (start, length) window, so a resumed engine reads exactly the sequences
    the uninterrupted run would have."""
    from repro.checkpoint import Checkpointer

    rng = np.random.default_rng(0)
    path = tmp_path / "tokens.bin"
    rng.integers(0, 250, size=8000).astype(np.uint16).tofile(path)
    data = TokenFileSource(str(path), seq_len=128)

    tr_a, _ = _trainer(m=2, h=4)
    ref = tr_a.init_state(jax.random.PRNGKey(0))
    ref, _ = SuperstepEngine(tr_a, data, 1).run(ref, 8)

    tr_b, _ = _trainer(m=2, h=4)
    st = tr_b.init_state(jax.random.PRNGKey(0))
    eng_b = SuperstepEngine(tr_b, data, 1)
    st, _ = eng_b.run(st, 5)
    eng_b.close()
    Checkpointer(str(tmp_path / "ck"), trainer=tr_b).save(st, 5)

    tr_c, _ = _trainer(m=2, h=4)
    restored, start = Checkpointer(str(tmp_path / "ck"), trainer=tr_c).restore()
    out, _ = SuperstepEngine(tr_c, data, 1).run(restored, 8, start=start)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# streaming fragment schedule regressions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,p", [(4, 1), (4, 2), (4, 4), (6, 3), (8, 4), (12, 4), (30, 5)])
def test_fragment_schedule_each_fragment_once_per_round(h, p):
    """Over every H-step round, each fragment must sync exactly once."""
    for r in range(3):
        due = [
            f
            for s in range(r * h + 1, (r + 1) * h + 1)
            for f in streaming.fragments_due(s, p, h)
        ]
        assert sorted(due) == list(range(p)), (h, p, r, due)


def test_fragments_gt_sync_every_rejected():
    with pytest.raises(ValueError):
        DiLoCoConfig(streaming_fragments=8, sync_every=4)
    with pytest.raises(ValueError):
        DiLoCoConfig(streaming_fragments=-1)
    DiLoCoConfig(streaming_fragments=4, sync_every=4)  # boundary is valid


def test_fragment_sync_static_partition_and_jit_cache():
    trainer, data = _trainer(m=2, h=4, streaming_fragments=2)
    sync = streaming.FragmentSync(trainer, donate=False)
    state = trainer.init_state(jax.random.PRNGKey(0))
    n_leaves = len(jax.tree.leaves(state["global_params"]))
    assert len(sync.assignment) == n_leaves
    assert sorted(set(sync.assignment)) == [0, 1]
    f0 = sync.jitted(0)
    assert sync.jitted(0) is f0  # cached, no retrace machinery per call
    state2 = f0(state)
    ref = streaming.outer_sync_fragment(trainer, state, 0)
    for a, b in zip(jax.tree.leaves(state2["global_params"]),
                    jax.tree.leaves(ref["global_params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
