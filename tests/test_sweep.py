"""Scaling-law sweep subsystem: grid expansion, ledger, per-cell resume,
run_experiment, the stacking planner + cell-batched runner, and the fit
stage."""
import json
import math
import os

import numpy as np
import pytest

from repro.configs import get_sweep
from repro.configs.sweeps import SweepSpec
from repro.launch.fit import fit_ledger
from repro.launch.sweep import (
    _arch_param_count,
    append_record,
    cell_config,
    cell_id,
    expand_grid,
    plan_groups,
    read_ledger,
    run_cell_batch,
    run_sweep,
    stack_key,
)
from repro.launch.train import ExperimentConfig, run_experiment, simulate_cell

TINY = SweepSpec(
    name="test",
    archs=("tiny-t0",),
    modes=("dp", "diloco"),
    replicas=(1,),
    sync_every=(2,),
    batch_tokens=(512,),
    seq_len=64,
    steps=4,
    lr=3e-3,
    warmup_frac=0.25,
    eval_batches=2,
    eval_seqs=4,
    checkpoint_every=2,
)


# ---------------------------------------------------------------------------
# Grid expansion / cell identity
# ---------------------------------------------------------------------------


def test_smoke_grid_expansion_collapses_dp_axes():
    cells = expand_grid(get_sweep("smoke"))
    # 2 archs x (1 dp + 2 diloco M values): dp ignores the M axis
    assert len(cells) == 6
    dp = [c for c in cells if c["mode"] == "dp"]
    assert len(dp) == 2
    assert all(c["m"] == 1 and c["h"] == 1 and c["outer_lr"] == 0.0 for c in dp)
    # ids are stable content hashes and unique
    ids = [cell_id(c) for c in cells]
    assert len(set(ids)) == len(ids)
    assert ids == [cell_id(c) for c in expand_grid(get_sweep("smoke"))]


def test_streaming_cells_clamp_fragments_to_h():
    sweep = TINY.replace(modes=("streaming",), sync_every=(2, 4),
                         streaming_fragments=3)
    cells = expand_grid(sweep)
    frags = {c["h"]: c["streaming_fragments"] for c in cells}
    assert frags == {2: 2, 4: 3}
    # the cell runs through the strategy registry, fragments in the spec
    from repro.core import sync

    for cell in cells:
        cfg = cell_config(sweep, cell, "")
        assert cfg.algorithm == "diloco"
        assert sync.parse_spec(cfg.sync) == sync.get(
            "streaming", fragments=cell["streaming_fragments"])
        assert cfg.streaming_fragments == 0  # legacy flag unused on this path


def test_paper_grid_is_the_papers_axes():
    cells = expand_grid(get_sweep("paper"))
    assert {c["m"] for c in cells if c["mode"] == "diloco"} == {1, 2, 4, 8}
    assert len({c["arch"] for c in cells}) == 7
    assert all(c["h"] in (1, 30) for c in cells)


def test_cell_id_is_engine_independent():
    """PR 1 proved the engines bitwise-equivalent, so a ledger produced on
    one engine must dedupe cells for the other: ``engine`` stays in the
    spec/record but is excluded from the id hash."""
    (spec,) = expand_grid(TINY.replace(modes=("diloco",)))
    assert spec["engine"] == "superstep"
    other = {**spec, "engine": "per-step"}
    assert cell_id(spec) == cell_id(other)
    # every other field still changes the id
    assert cell_id({**spec, "lr": 9e-9}) != cell_id(spec)
    assert cell_id({**spec, "seed": 123}) != cell_id(spec)


def test_param_count_memoized_per_arch(monkeypatch):
    """Grid expansion must build each arch's model once, not once per
    (arch, batch_tokens) pair — param_count is a pure function of the
    config."""
    from repro.launch import sweep as sweep_mod

    _arch_param_count.cache_clear()
    calls = []
    real = sweep_mod.build_model

    def counting(cfg):
        calls.append(cfg.name)
        return real(cfg)

    monkeypatch.setattr(sweep_mod, "build_model", counting)
    sw = TINY.replace(steps=0, min_steps=2,
                      batch_tokens=(512, 1024, 2048))
    cells = expand_grid(sw)
    assert len({c["batch_tokens"] for c in cells}) == 3
    assert len(calls) == 1  # one arch -> one model build
    expand_grid(sw)
    assert len(calls) == 1  # re-expansion is free


# ---------------------------------------------------------------------------
# Stacking planner
# ---------------------------------------------------------------------------


def test_smoke_stack_grid_is_one_stackable_group_per_mode():
    """diloco and int4 each form one 6-cell (lr x seed) stacked group; the
    int4 half keeps the registry-only strategy path in the CI smoke bench
    (results/BENCH_sweep_smoke.json)."""
    cells = expand_grid(get_sweep("smoke-stack"))
    assert len(cells) == 12
    assert {c["mode"] for c in cells} == {"diloco", "int4"}
    assert len({stack_key(c) for c in cells}) == 2  # one per mode
    for mode in ("diloco", "int4"):
        sub = [c for c in cells if c["mode"] == mode]
        assert {(c["lr"], c["seed"]) for c in sub} == {
            (lr, s) for lr in (3e-3, 2e-3, 1e-3) for s in (0, 1)}
    plan = plan_groups(cells)
    assert set(plan) == {cell_id(c) for c in cells}
    groups = {id(g): g for g in plan.values()}.values()
    assert sorted(len(g) for g in groups) == [6, 6]
    for g in groups:  # modes never stack together
        assert len({s["mode"] for s in g}) == 1


def test_plan_groups_rules(tmp_path):
    sw = TINY.replace(modes=("dp", "diloco"), seeds=(0, 1))
    cells = expand_grid(sw)  # 2 dp + 2 diloco (seed axis)
    plan = plan_groups(cells)
    assert len(plan) == 4
    groups = {id(g): g for g in plan.values()}.values()
    assert sorted(len(g) for g in groups) == [2, 2]
    for g in groups:  # dp and diloco never stack together
        assert len({s["mode"] for s in g}) == 1

    # max_group chunks an oversized bucket; the leftover singleton runs
    # sequentially (absent from the plan)
    cells5 = expand_grid(TINY.replace(modes=("diloco",), seeds=(0, 1, 2, 3, 4)))
    plan5 = plan_groups(cells5, max_group=2)
    assert len(plan5) == 4
    assert sorted(len(g) for g in {id(g): g for g in plan5.values()}.values()) == [2, 2]

    # a cell with existing checkpoints keeps its step-level resume: it is
    # routed to the sequential path
    victim = cells[0]
    os.makedirs(tmp_path / cell_id(victim) / "step_0000000002")
    plan_ck = plan_groups(cells, checkpoint_root=str(tmp_path))
    assert cell_id(victim) not in plan_ck

    # non-superstep cells cannot stack
    per_step = [{**c, "engine": "per-step"} for c in cells]
    assert plan_groups(per_step) == {}


def test_stacked_sweep_matches_sequential_ledger_all_modes(tmp_path, monkeypatch):
    """Acceptance: stacked and sequential runs of the same grid produce
    identical ledger records cell-for-cell (eval losses bitwise), across
    all five sync modes (including the registry-only int4 strategy) — and
    the stacked run actually took the batched path."""
    sw = SweepSpec(
        name="stack5",
        archs=("tiny-t0",),
        modes=("dp", "diloco", "int8", "int4", "streaming"),
        replicas=(2,),
        sync_every=(2,),
        batch_tokens=(512,),
        seq_len=64,
        steps=4,
        lr=3e-3,
        seeds=(0, 1),
        warmup_frac=0.25,
        eval_batches=1,
        eval_seqs=4,
    )
    cells = expand_grid(sw)
    assert len(cells) == 10  # 5 modes x 2 seeds (dp collapses M/H)
    groups = {id(g): g for g in plan_groups(cells).values()}.values()
    assert sorted(len(g) for g in groups) == [2, 2, 2, 2, 2]

    from repro.launch import sweep as sweep_mod

    batched = []
    real = sweep_mod.run_cell_batch
    monkeypatch.setattr(
        sweep_mod, "run_cell_batch",
        lambda *a, **kw: (batched.append(len(a[1])), real(*a, **kw))[1])

    led_stacked = str(tmp_path / "stacked.jsonl")
    led_seq = str(tmp_path / "seq.jsonl")
    out_stacked = run_sweep(sw, led_stacked, quiet=True, stack=True)
    out_seq = run_sweep(sw, led_seq, quiet=True, stack=False)
    assert batched == [2, 2, 2, 2, 2]
    assert not any(r["skipped"] for r in out_stacked + out_seq)

    a, b = read_ledger(led_stacked), read_ledger(led_seq)
    assert set(a) == set(b) == {cell_id(c) for c in cells}
    for cid in a:
        for key in a[cid]:
            if key == "runtime_s":
                continue
            assert a[cid][key] == b[cid][key], (cid, key)


def test_run_cell_batch_records_match_run_experiment():
    """Single-group equivalence at the API level (no ledger): records are
    field-for-field identical to run_experiment up to runtime_s."""
    sw = get_sweep("smoke-stack")
    specs = expand_grid(sw)[:2]
    recs = run_cell_batch(sw, specs)
    for spec, rec in zip(specs, recs):
        seq = run_experiment(cell_config(sw, spec, "")).to_record()
        for key in seq:
            if key == "runtime_s":
                continue
            assert seq[key] == rec[key], (key, seq[key], rec[key])


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------


def test_ledger_roundtrip_and_truncated_tail(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    recs = [
        {"schema": 1, "cell": "aaa", "final_eval": 1.0},
        {"schema": 1, "cell": "bbb", "final_eval": 2.0},
    ]
    for r in recs:
        append_record(path, r)
    # simulate a crash mid-append: truncated trailing line
    with open(path, "a") as f:
        f.write('{"schema": 1, "cell": "ccc", "final_ev')
    done = read_ledger(path)
    assert set(done) == {"aaa", "bbb"}
    assert done["bbb"]["final_eval"] == 2.0
    # unknown schema versions are ignored, not misread
    append_record(path, {"schema": 99, "cell": "ddd"})
    assert set(read_ledger(path)) == {"aaa", "bbb"}


def test_ledger_warns_on_midfile_garbage(tmp_path):
    """Damage BETWEEN intact records is not a benign crash artifact (that's
    only ever the tail): read_ledger must warn — the affected cells will
    silently re-run — while still returning every parseable record."""
    path = str(tmp_path / "ledger.jsonl")
    append_record(path, {"schema": 1, "cell": "aaa", "final_eval": 1.0})
    with open(path, "a") as f:
        f.write("%% not json at all %%\n")
    append_record(path, {"schema": 1, "cell": "bbb", "final_eval": 2.0})
    with pytest.warns(UserWarning, match="line 2"):
        done = read_ledger(path)
    assert set(done) == {"aaa", "bbb"}


def test_ledger_error_records_do_not_mark_cells_done(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    append_record(path, {"schema": 1, "cell": "aaa", "final_eval": 1.0})
    append_record(path, {"schema": 1, "cell": "bbb", "sweep": "test",
                         "spec": {}, "error": "RuntimeError: boom"})
    done = read_ledger(path)
    assert set(done) == {"aaa"}  # the failed cell stays eligible to re-run


def test_run_sweep_contains_cell_failures(tmp_path):
    """A cell whose attempts are exhausted is contained — error record in
    the ledger, sweep stays alive — and a later sweep picks it back up."""
    from repro.core import faults

    ledger = str(tmp_path / "ledger.jsonl")
    ckpt = str(tmp_path / "ckpt")
    with faults.inject("io:op=cell_run,fails=2") as inj:
        out = run_sweep(TINY, ledger, ckpt, quiet=True, stack=False,
                        cell_retries=1)
    assert inj.raised == {"cell_run": 2}  # both attempts of the first cell
    failed = [r for r in out if r.get("error")]
    ok = [r for r in out if r["record"]]
    assert len(failed) == 1 and len(ok) == 1
    assert failed[0]["record"] is None
    assert len(read_ledger(ledger)) == 1

    out2 = run_sweep(TINY, ledger, ckpt, quiet=True, stack=False)
    assert all(r["record"] for r in out2)
    assert sum(r["skipped"] for r in out2) == 1
    assert len(read_ledger(ledger)) == 2


def test_ledger_never_emits_bare_nan_tokens(tmp_path):
    """A zero-new-steps resume records final_train=NaN; the ledger must
    stay strict JSON (NaN/Infinity tokens break jq / JSON.parse)."""
    path = str(tmp_path / "ledger.jsonl")
    append_record(path, {"schema": 1, "cell": "eee",
                         "final_train": float("nan"),
                         "sim": {"x": float("inf"), "ok": [1.0, float("-inf")]}})
    raw = open(path).read()
    assert "NaN" not in raw and "Infinity" not in raw
    rec = read_ledger(path)["eee"]
    assert rec["final_train"] is None
    assert rec["sim"]["x"] is None and rec["sim"]["ok"] == [1.0, None]


# ---------------------------------------------------------------------------
# Driving (real training on a minuscule grid)
# ---------------------------------------------------------------------------


def test_sweep_runs_records_and_skips(tmp_path):
    ledger = str(tmp_path / "SWEEP_test.jsonl")
    out = run_sweep(TINY, ledger, str(tmp_path / "ckpt"), quiet=True)
    assert len(out) == 2 and not any(r["skipped"] for r in out)
    for r in out:
        rec = r["record"]
        assert rec["schema"] == 1
        assert rec["steps"] == 4 and rec["tokens"] == 4 * 512
        assert math.isfinite(rec["final_eval"])
        assert rec["sim"]["wallclock"]["total_s"] > 0
        assert 0 < rec["sim"]["cu_at_medium_bw"] <= 1
        assert rec["config"]["arch"] == "tiny-t0"
    # a second run skips everything via the ledger
    again = run_sweep(TINY, ledger, str(tmp_path / "ckpt"), quiet=True)
    assert all(r["skipped"] for r in again)
    # ledger did not grow
    assert len(read_ledger(ledger)) == 2


def test_cell_checkpoint_resume_reproduces_eval_bitwise(tmp_path):
    """Kill-and-rerun inside a cell: with the ledger record gone but the
    cell's checkpoints intact, the rerun restores at the final step (zero
    training) and reproduces the recorded eval loss bitwise."""
    sweep = TINY.replace(modes=("diloco",))
    ledger = str(tmp_path / "SWEEP_test.jsonl")
    first = run_sweep(sweep, ledger, str(tmp_path / "ckpt"), quiet=True)
    (rec,) = [r["record"] for r in first]
    assert rec["start_step"] == 0
    os.remove(ledger)
    second = run_sweep(sweep, ledger, str(tmp_path / "ckpt"), quiet=True)
    (rec2,) = [r["record"] for r in second]
    assert rec2["start_step"] == rec2["steps"] == 4  # no steps re-trained
    assert rec2["final_eval"] == rec["final_eval"]


def test_sweep_cell_m1_h1_matches_dp_eval():
    """Acceptance: a DiLoCo cell with M=1, H=1 and an identity outer step
    (eta=1, mu=0, no Nesterov) is algebraically the DP recursion; its eval
    loss must match the plain DP train path to float rounding."""
    base = dict(arch="tiny-t0", batch_tokens=512, seq_len=64, steps=8,
                lr=3e-3, warmup=2, eval_batches=2, eval_seqs=4, seed=0)
    dp = run_experiment(ExperimentConfig(algorithm="dp", **base))
    dl = run_experiment(ExperimentConfig(
        algorithm="diloco", replicas=1, sync_every=1,
        outer_lr=1.0, outer_momentum=0.0, nesterov=False, **base))
    assert dp.steps == dl.steps == 8
    np.testing.assert_allclose(dl.final_eval, dp.final_eval, rtol=1e-4, atol=1e-4)
    # per-step train losses track each other too
    np.testing.assert_allclose(
        [h["loss"] for h in dl.history], [h["loss"] for h in dp.history],
        rtol=1e-3, atol=1e-3)


def test_run_experiment_result_record_is_json_serializable():
    cfg = ExperimentConfig(arch="tiny-t0", algorithm="dp", batch_tokens=512,
                           seq_len=64, steps=2, warmup=1, eval_batches=1,
                           eval_seqs=2)
    res = run_experiment(cfg)
    rec = res.to_record()
    rt = json.loads(json.dumps(rec))
    assert rt["config"]["arch"] == "tiny-t0"
    assert rt["n_params"] == res.n_params > 0
    assert rt["start_step"] == 0


# ---------------------------------------------------------------------------
# Simulation attachment
# ---------------------------------------------------------------------------


def test_simulate_cell_diloco_beats_dp_on_wallclock():
    """At scale, the cell simulation must reproduce the paper's core claim:
    DiLoCo M>=2 needs far less cross-DC comm time than DP and idles less."""
    kw = dict(batch_tokens=2 ** 20, seq_len=2048, steps=0)
    n, tokens = int(1e9), int(20e9)
    dp = simulate_cell(n, tokens, ExperimentConfig(algorithm="dp", **kw))
    dl = simulate_cell(n, tokens, ExperimentConfig(
        algorithm="diloco", replicas=4, sync_every=30, **kw))
    assert dl["wallclock"]["comm_s"] < dp["wallclock"]["comm_s"]
    assert dl["cu_at_medium_bw"] > dp["cu_at_medium_bw"]
    int8 = simulate_cell(n, tokens, ExperimentConfig(
        algorithm="diloco", replicas=4, sync_every=30, compression="int8", **kw))
    assert int8["cu_at_medium_bw"] >= dl["cu_at_medium_bw"]
    assert int8["outer_payload_ratio"] == 2.0
    # outer comm is now actually billed at the compressed payload
    assert int8["wallclock"]["comm_s"] < dl["wallclock"]["comm_s"]
    # the registry-only int4 strategy routes through the same accounting
    int4 = simulate_cell(n, tokens, ExperimentConfig(
        algorithm="diloco", replicas=4, sync_every=30, sync="int4", **kw))
    assert int4["outer_payload_ratio"] == 4.0
    assert int4["cu_at_medium_bw"] >= int8["cu_at_medium_bw"]
    assert int4["wallclock"]["comm_s"] < int8["wallclock"]["comm_s"]


# ---------------------------------------------------------------------------
# Fit stage (synthetic ledgers — no training)
# ---------------------------------------------------------------------------


def _synth_record(arch, n, mode, m, b, eval_loss, h=30, tokens=0):
    spec = {"arch": arch, "mode": mode, "m": m, "h": h if mode != "dp" else 1,
            "batch_tokens": b, "seq_len": 128, "steps": 100, "lr": 1e-3,
            "outer_lr": 0.7 if mode != "dp" else 0.0,
            "outer_momentum": 0.9 if mode != "dp" else 0.0,
            "nesterov": mode != "dp", "streaming_fragments": 0, "seed": 0,
            "engine": "superstep"}
    return {"schema": 1, "cell": cell_id(spec), "spec": spec,
            "n_params": n, "steps": 100, "tokens": tokens or 100 * b,
            "final_eval": eval_loss, "final_eval_sem": 0.0,
            "final_train": eval_loss, "runtime_s": 1.0,
            "sim": {"wallclock": {"total_s": 1.0, "comm_s": 0.1},
                    "cu_at_medium_bw": 0.9}}


def test_fit_ledger_recovers_joint_power_law():
    A, alpha, beta = 19.0, -0.098, 0.012
    recs = []
    for i, n in enumerate(np.geomspace(3e7, 3e9, 5)):
        for m in (1, 2, 4, 8):
            loss = A * n ** alpha * m ** beta
            recs.append(_synth_record(f"a{i}", n, "diloco", m, 2048, loss))
        recs.append(_synth_record(f"a{i}", n, "dp", 1, 2048, A * n ** alpha))
    fits = fit_ledger(recs, restarts=8)
    assert fits["n_cells"] == len(recs)
    j = fits["joint"]
    assert abs(j["alpha"] - alpha) < 1e-3 and abs(j["beta"] - beta) < 1e-3
    assert j["residual"] < 1e-6
    pl = fits["power_laws"]
    assert abs(pl["diloco_m8"]["alpha"] - alpha) < 1e-3
    assert abs(pl["dp_m1"]["alpha"] - alpha) < 1e-3
    # parametric form 1 is the same family -> near-zero residual
    p1 = fits["parametric"]["AN^aM^b"]
    assert p1["residual"] < 1e-2
    rows = fits["headline"]["diloco_vs_dp"]
    assert len(rows) == 5 and all("diloco_m2_minus_dp" in r for r in rows)


def test_fit_ledger_optimal_batch_growth_with_m():
    """B_opt from the quadratic-in-log2(B) fit must grow with M (Finding 3)
    and the growth itself must fit a power law in M."""
    recs = []
    n = 1e8
    for m in (1, 2, 4, 8):
        b_opt = 2 ** (8 + np.log2(m))  # optimum doubles with M
        for b in (64, 256, 1024, 4096):
            loss = 2.5 + 0.02 * (np.log2(b) - np.log2(b_opt)) ** 2
            recs.append(_synth_record("a", n, "diloco", m, b, loss))
    fits = fit_ledger(recs, restarts=4)
    per = fits["optimal_batch"]["per_cell"]
    opts = {v["m"]: v["b_opt"] for v in per.values()}
    assert opts[1] < opts[2] < opts[4] < opts[8]
    growth = fits["optimal_batch"]["growth_with_m"]
    (g,) = growth.values()
    assert abs(g["gamma"] - 1.0) < 0.05  # doubles with M -> exponent ~1


def test_fit_ledger_skips_underdetermined_fits():
    recs = [_synth_record("a", 1e8, "diloco", 1, 2048, 3.0)]
    fits = fit_ledger(recs, restarts=2)
    assert fits["power_laws"] == {}
    assert "skipped" in fits["joint"]
    assert "skipped" in fits["parametric"]
    assert fits["optimal_batch"]["per_cell"] == {}
