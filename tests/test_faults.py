"""Fault-tolerant runtime tests: deterministic schedules, bounded-backoff
retry, the global injector, partial-participation outer sync (normalized
weights, reseed-on-rejoin, engine equivalence under mask sequences with
zero recompiles), manifest-v3 checkpoint checksums with corrupt-fallback,
and the wallclock straggler term."""
import json
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import SCHEMA_VERSION, Checkpointer, CorruptCheckpointError
from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core import elastic, faults, jitcache, retry, wallclock
from repro.core.cellbatch import CellBatchEngine, stack_trees, unstack_tree
from repro.core.diloco import make_trainer
from repro.core.superstep import SuperstepEngine
from repro.data import SyntheticLM


def _trainer(m=2, h=4, seq_len=64, data_seed=1234, **kw):
    cfg = get_config("tiny-t0")
    from repro.models import build_model

    model = build_model(cfg)
    tcfg = TrainConfig(global_batch_tokens=4 * seq_len, seq_len=seq_len, steps=50)
    dkw = dict(num_replicas=m, sync_every=h)
    dkw.update(kw)
    trainer = make_trainer(
        model, DiLoCoConfig(**dkw),
        OptimizerConfig(peak_lr=1e-3, warmup_steps=5), tcfg,
    )
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq_len, seed=data_seed)
    return trainer, data


# ---------------------------------------------------------------------------
# FaultSchedule
# ---------------------------------------------------------------------------


def test_schedule_spec_roundtrip():
    spec = ("crash:replica=1,at=2,rejoin=4;"
            "straggle:replica=0,start=1,stop=3,factor=2.5;"
            "io:op=ledger_append,fails=2;corrupt:step=30;seed=7")
    s = faults.parse(spec)
    assert s.seed == 7
    assert faults.parse(s.spec()) == s
    assert s.spec() == spec
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults.parse("explode:now=1")
    with pytest.raises(ValueError, match="bad option"):
        faults.parse("crash:when=2")


def test_schedule_masks_and_rejoin():
    s = faults.parse("crash:replica=1,at=2,rejoin=4")
    np.testing.assert_array_equal(s.participation_mask(1, 3), [True, True, True])
    np.testing.assert_array_equal(s.participation_mask(2, 3), [True, False, True])
    np.testing.assert_array_equal(s.participation_mask(3, 3), [True, False, True])
    np.testing.assert_array_equal(s.participation_mask(4, 3), [True, True, True])
    # rejoin fires exactly on the first participating round after death
    assert not s.rejoin_mask(0, 3).any()
    assert not s.rejoin_mask(2, 3).any()
    np.testing.assert_array_equal(s.rejoin_mask(4, 3), [False, True, False])
    # rejoin=-1: dead forever
    forever = faults.parse("crash:replica=0,at=1")
    assert not forever.participation_mask(100, 2)[0]


def test_schedule_slowdowns():
    s = faults.parse(
        "straggle:replica=0,start=1,stop=3,factor=2.5;crash:replica=0,at=2,rejoin=3")
    assert s.round_slowdown(0, 2) == 1.0
    assert s.round_slowdown(1, 2) == 2.5
    # round 2: the straggler is dead — survivors gate the round at 1.0
    assert s.round_slowdown(2, 2) == 1.0
    assert s.mean_slowdown(4, 2) == pytest.approx((1.0 + 2.5 + 1.0 + 1.0) / 4)
    assert s.mean_slowdown(0, 2) == 1.0


def test_schedule_random_is_explicit_and_deterministic():
    a = faults.FaultSchedule.random(11, m=4, rounds=6)
    b = faults.FaultSchedule.random(11, m=4, rounds=6)
    assert a == b
    assert faults.parse(a.spec()) == a  # events are explicit, not seed-lazy


# ---------------------------------------------------------------------------
# retry
# ---------------------------------------------------------------------------


def test_retry_backoff_sequence_and_success():
    slept, attempts = [], []

    def flaky():
        attempts.append(1)
        if len(attempts) < 3:
            raise OSError("transient")
        return "ok"

    policy = retry.Policy(attempts=4, base_delay=0.05, multiplier=2.0)
    out = retry.call(flaky, policy=policy, sleep=slept.append)
    assert out == "ok" and len(attempts) == 3
    assert slept == [0.05, 0.1]  # deterministic clock: exact delays


def test_retry_exhaustion_and_passthrough():
    def always_fails():
        raise OSError("always")

    with pytest.raises(OSError, match="always"):
        retry.call(always_fails,
                   policy=retry.Policy(attempts=2, base_delay=0.0),
                   sleep=lambda _: None)
    calls = []

    def value_error():
        calls.append(1)
        raise ValueError("not retryable")

    with pytest.raises(ValueError):
        retry.call(value_error, sleep=lambda _: None)
    assert len(calls) == 1  # non-retry_on exceptions propagate immediately
    assert list(retry.delays(retry.Policy(attempts=4, base_delay=1.0,
                                          multiplier=3.0, max_delay=4.0))) \
        == [1.0, 3.0, 4.0]


def test_injector_io_check_counts():
    assert faults.active() is None
    faults.io_check("anything")  # no-op without an injector
    with faults.inject("io:op=ledger_append,fails=2") as inj:
        for _ in range(2):
            with pytest.raises(OSError, match="transient ledger_append"):
                faults.io_check("ledger_append")
        faults.io_check("ledger_append")  # exhausted
        faults.io_check("other_op")       # never scheduled
        assert inj.calls == {"ledger_append": 3, "other_op": 1}
        assert inj.raised == {"ledger_append": 2}
        with pytest.raises(RuntimeError, match="already active"):
            with faults.inject(faults.FaultSchedule()):
                pass
    assert faults.active() is None


# ---------------------------------------------------------------------------
# participation_weights / reseed
# ---------------------------------------------------------------------------


def test_participation_weights_all_dead_raises():
    with pytest.raises(ValueError, match="all-dead"):
        elastic.participation_weights(np.zeros(4, bool))


def test_participation_weights_single_survivor():
    w = np.asarray(elastic.participation_weights(np.array([0, 0, 1, 0], bool)))
    np.testing.assert_array_equal(w, [0.0, 0.0, 1.0, 0.0])


def test_participation_weights_sum_to_one_float32():
    for mask in ([1, 1, 1, 0], [1, 1, 1], [1, 0, 1, 1, 0, 1, 1]):
        w = np.asarray(elastic.participation_weights(np.array(mask, bool)))
        assert w.dtype == np.float32
        assert abs(float(w.sum()) - 1.0) <= 1e-6
        assert (w[~np.array(mask, bool)] == 0).all()


def test_reseed_replicas_cold_starts_rejoiners():
    trainer, data = _trainer(m=2, h=4)
    inner = jax.jit(trainer.inner_step)
    state = trainer.init_state(jax.random.PRNGKey(0))
    for t in range(3):  # no sync: replicas diverge, moments/count accrue
        state, _ = inner(state, data.global_batch(t, 2, 2))
    ref = jax.tree.map(np.asarray, state)

    state = elastic.reseed_replicas(trainer, state, np.array([False, True]))
    for g, p in zip(jax.tree.leaves(ref["global_params"]),
                    jax.tree.leaves(state["inner_params"])):
        np.testing.assert_array_equal(np.asarray(p[1]), g)  # reseeded
    for old, new in zip(jax.tree.leaves(ref["inner_params"]),
                        jax.tree.leaves(state["inner_params"])):
        np.testing.assert_array_equal(np.asarray(new[0]), old[0])  # untouched
    for leaf in jax.tree.leaves(state["inner_opt"]["m"]) + \
            jax.tree.leaves(state["inner_opt"]["v"]):
        assert not np.asarray(leaf[1]).any()
    count = np.asarray(state["inner_opt"]["count"])
    assert count[1] == 0 and count[0] == 3  # cold-start bias correction
    for old, new in zip(jax.tree.leaves(ref["inner_opt"]["m"]),
                        jax.tree.leaves(state["inner_opt"]["m"])):
        np.testing.assert_array_equal(np.asarray(new[0]), old[0])


def test_reseed_zeroes_error_feedback():
    trainer, data = _trainer(m=2, h=2, compression="int8")
    inner = jax.jit(trainer.inner_step)
    outer = trainer.jit_outer_sync()
    state = trainer.init_state(jax.random.PRNGKey(0))
    for t in range(2):
        state, _ = inner(state, data.global_batch(t, 2, 2))
    state = outer(state)  # quantized sync populates the EF residuals
    assert any(np.asarray(l).any() for l in jax.tree.leaves(state["ef"]))
    state = elastic.reseed_replicas(trainer, state, np.array([False, True]))
    for leaf in jax.tree.leaves(state["ef"]):
        arr = np.asarray(leaf)
        assert not arr[1].any(), "rejoiner EF must be zeroed"


# ---------------------------------------------------------------------------
# engine equivalence + zero recompiles under mask sequences
# ---------------------------------------------------------------------------

# rounds of H=2: all alive -> replica 1 dead -> rejoin (reseed at round 2)
_MASKS = {0: [True, True, True], 1: [True, False, True], 2: [True, True, True]}


def _round_weights(rnd):
    return elastic.participation_weights(np.array(_MASKS[rnd], bool))


def _rejoin(rnd):
    if rnd == 0:
        return np.zeros(3, bool)
    return np.array(_MASKS[rnd], bool) & ~np.array(_MASKS[rnd - 1], bool)


def _per_step_masked(steps=6, seqs=2):
    trainer, data = _trainer(m=3, h=2)
    state = trainer.init_state(jax.random.PRNGKey(0))
    inner = jax.jit(trainer.inner_step)
    outer = jax.jit(trainer.outer_sync)
    losses = []
    for t in range(steps):
        if t % 2 == 0 and _rejoin(t // 2).any():
            state = elastic.reseed_replicas(trainer, state, _rejoin(t // 2))
        state, met = inner(state, data.global_batch(t, 3, seqs))
        losses.append(float(met["loss"]))
        if (t + 1) % 2 == 0:
            state = outer(state, _round_weights(t // 2))
    return state, losses


def _superstep_masked(steps=6, seqs=2):
    trainer, data = _trainer(m=3, h=2)
    engine = SuperstepEngine(trainer, data, seqs)
    state = trainer.init_state(jax.random.PRNGKey(0))
    losses = []
    step = 0
    while step < steps:
        end, _ = engine.round_bounds(step, steps)
        rnd = step // 2
        if _rejoin(rnd).any():
            state = elastic.reseed_replicas(trainer, state, _rejoin(rnd))
        state, mets = engine.run_round(state, step, end - step,
                                       weights=_round_weights(rnd))
        losses.extend(float(x) for x in np.atleast_1d(mets["loss"]))
        step = end
    return state, losses


def _cellbatch_masked(steps=6, seqs=2, k=2):
    pairs = [_trainer(m=3, h=2) for _ in range(k)]
    trainers = [t for t, _ in pairs]
    datas = [d for _, d in pairs]
    engine = CellBatchEngine(trainers, datas, seqs)
    states = engine.init_states([0] * k)
    losses = []
    step = 0
    while step < steps:
        end, _ = engine.round_bounds(step, steps)
        rnd = step // 2
        if _rejoin(rnd).any():
            states = stack_trees([
                elastic.reseed_replicas(trainers[i],
                                        unstack_tree(states, i), _rejoin(rnd))
                for i in range(k)
            ])
        w = np.tile(np.asarray(_round_weights(rnd))[None], (k, 1))
        states, mets = engine.run_round(states, step, end - step, weights=w)
        losses.append(np.atleast_2d(mets["loss"]))
        step = end
    per_cell = np.concatenate(losses, axis=1)
    return engine.unstack(states)[0], [float(x) for x in per_cell[0]]


def test_engines_agree_bitwise_under_mask_sequence():
    """Per-step, superstep, and cellbatch must produce identical losses AND
    identical final states under a crash/rejoin mask sequence — partial
    participation is engine-invariant."""
    state_ref, losses_ref = _per_step_masked()
    state_ss, losses_ss = _superstep_masked()
    state_cb, losses_cb = _cellbatch_masked()
    assert losses_ss == losses_ref
    assert losses_cb == losses_ref
    for name, state in (("superstep", state_ss), ("cellbatch", state_cb)):
        for key in ("inner_params", "global_params", "inner_opt", "outer_m"):
            for a, b in zip(jax.tree.leaves(state[key]),
                            jax.tree.leaves(state_ref[key])):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{name} state[{key!r}] diverged")


def test_mask_changes_cause_zero_recompiles():
    """Participation weights are a traced operand: after the first weighted
    round, every further mask value must reuse the SAME executables
    (jitcache build-count flat) on both engines."""
    trainer, data = _trainer(m=3, h=2)
    engine = SuperstepEngine(trainer, data, 2)
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, _ = engine.run_round(
        state, 0, 2, weights=elastic.participation_weights(np.ones(3, bool)))
    builds = jitcache.build_count()
    for rnd, mask in enumerate(([1, 0, 1], [0, 1, 1], [1, 1, 0]), start=1):
        w = elastic.participation_weights(np.array(mask, bool))
        state, _ = engine.run_round(state, rnd * 2, 2, weights=w)
    assert jitcache.build_count() == builds, "mask change recompiled"

    pairs = [_trainer(m=3, h=2) for _ in range(2)]
    cb = CellBatchEngine([t for t, _ in pairs], [d for _, d in pairs], 2)
    states = cb.init_states([0, 0])
    states, _ = cb.run_round(states, 0, 2, weights=np.full((2, 3), 1 / 3))
    builds = jitcache.build_count()
    states, _ = cb.run_round(
        states, 2, 2, weights=np.tile([[0.5, 0.0, 0.5]], (2, 1)))
    assert jitcache.build_count() == builds, "stacked mask change recompiled"


# ---------------------------------------------------------------------------
# checkpoint: v3 checksums, corruption fallback, retried I/O
# ---------------------------------------------------------------------------


def test_checkpoint_corrupt_newest_falls_back(tmp_path):
    trainer, _ = _trainer(m=2, h=2)
    ckpt = Checkpointer(str(tmp_path), trainer=trainer)
    state = trainer.init_state(jax.random.PRNGKey(0))
    ckpt.save(state, 2)
    ckpt.save(state, 4)
    man = json.load(open(tmp_path / f"step_{4:010d}" / "manifest.json"))
    assert man["schema"] == SCHEMA_VERSION and man["checksums"]

    # content corruption: the archive stays loadable, only checksums catch it
    faults.corrupt_npz(str(tmp_path / f"step_{4:010d}" / "state.npz"))
    with pytest.warns(UserWarning, match="failed verification"):
        restored, step = ckpt.restore()
    assert step == 2
    for a, b in zip(jax.tree.leaves(restored["inner_params"]),
                    jax.tree.leaves(state["inner_params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # an explicitly requested step must raise, never silently fall back
    with pytest.raises(CorruptCheckpointError, match="checksum"):
        ckpt.restore(step=4)

    faults.corrupt_npz(str(tmp_path / f"step_{2:010d}" / "state.npz"))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(CorruptCheckpointError, match="no intact"):
            ckpt.restore()


def test_checkpoint_v2_manifest_restores_without_checksums(tmp_path):
    trainer, _ = _trainer(m=2, h=2)
    ckpt = Checkpointer(str(tmp_path), trainer=trainer)
    state = trainer.init_state(jax.random.PRNGKey(0))
    ckpt.save(state, 3)
    mpath = tmp_path / f"step_{3:010d}" / "manifest.json"
    man = json.load(open(mpath))
    del man["checksums"]
    man["schema"] = 2
    json.dump(man, open(mpath, "w"))
    _, step = ckpt.restore()  # pre-v3 checkpoints load unverified
    assert step == 3


def test_checkpoint_save_retries_transient_io(tmp_path):
    trainer, _ = _trainer(m=2, h=2)
    ckpt = Checkpointer(str(tmp_path), trainer=trainer)
    state = trainer.init_state(jax.random.PRNGKey(0))
    with faults.inject("io:op=checkpoint_save,fails=1") as inj:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            ckpt.save(state, 2)
    assert inj.raised == {"checkpoint_save": 1}
    assert ckpt.latest_step() == 2

    # more failures than attempts: the final error propagates
    with faults.inject("io:op=checkpoint_save,fails=10"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with pytest.raises(OSError, match="transient checkpoint_save"):
                ckpt.save(state, 4)


def test_checkpoint_restore_retries_transient_io(tmp_path):
    trainer, _ = _trainer(m=2, h=2)
    ckpt = Checkpointer(str(tmp_path), trainer=trainer)
    state = trainer.init_state(jax.random.PRNGKey(0))
    ckpt.save(state, 2)
    with faults.inject("io:op=checkpoint_restore,fails=1") as inj:
        _, step = ckpt.restore()
    assert step == 2 and inj.raised == {"checkpoint_restore": 1}


def test_injected_corruption_fires_on_scheduled_step(tmp_path):
    trainer, _ = _trainer(m=2, h=2)
    ckpt = Checkpointer(str(tmp_path), trainer=trainer)
    state = trainer.init_state(jax.random.PRNGKey(0))
    with faults.inject("corrupt:step=4") as inj:
        ckpt.save(state, 2)
        ckpt.save(state, 4)
    assert [s for s, _ in inj.corrupted] == [4]
    with pytest.warns(UserWarning, match="failed verification"):
        _, step = ckpt.restore()
    assert step == 2


# ---------------------------------------------------------------------------
# wallclock straggler term
# ---------------------------------------------------------------------------


def test_wallclock_straggler_term():
    kw = dict(algorithm="diloco", m_replicas=4, sync_every=30)
    base = wallclock.train_time(1e8, 2e9, 2 ** 16, **kw)
    default = wallclock.train_time(1e8, 2e9, 2 ** 16, straggler_factor=1.0, **kw)
    assert default == base and "straggler_s" not in base  # bitwise-identical
    slow = wallclock.train_time(1e8, 2e9, 2 ** 16, straggler_factor=2.0, **kw)
    assert slow["compute_s"] == 2 * base["compute_s"]
    assert slow["straggler_s"] == base["compute_s"]
    assert slow["comm_s"] == base["comm_s"]
    assert slow["total_s"] == slow["compute_s"] + slow["comm_s"]
    with pytest.raises(ValueError, match="straggler_factor"):
        wallclock.train_time(1e8, 2e9, 2 ** 16, straggler_factor=0.5, **kw)


def test_simulate_cell_bills_schedule_stragglers():
    from repro.launch.train import ExperimentConfig, simulate_cell

    cfg = ExperimentConfig(arch="tiny-t0", algorithm="diloco", replicas=2,
                           sync_every=5, batch_tokens=2048, seq_len=128)
    clean = simulate_cell(int(1e7), int(2048 * 20), cfg)
    chaotic = simulate_cell(
        int(1e7), int(2048 * 20),
        cfg.replace(faults="straggle:replica=0,start=0,stop=4,factor=3"))
    assert "straggler_s" not in clean["wallclock"]
    assert chaotic["wallclock"]["straggler_s"] > 0
    assert chaotic["wallclock"]["total_s"] > clean["wallclock"]["total_s"]


# ---------------------------------------------------------------------------
# train-loop wiring (CLI --faults)
# ---------------------------------------------------------------------------


def test_train_loop_engines_agree_under_fault_schedule():
    """run_experiment with --faults: superstep and per-step drivers place
    masks and re-seeds identically (absolute-round indexing)."""
    from repro.launch.train import ExperimentConfig, run_experiment

    cfg = ExperimentConfig(
        arch="tiny-t0", algorithm="diloco", replicas=3, sync_every=2,
        steps=6, batch_tokens=768, seq_len=64, warmup=2, eval_every=0,
        log_every=0, eval_batches=1,
        faults="crash:replica=1,at=1,rejoin=2")
    r_ss = run_experiment(cfg.replace(engine="superstep"))
    r_ps = run_experiment(cfg.replace(engine="per-step"))
    assert [h["loss"] for h in r_ss.history] == [h["loss"] for h in r_ps.history]
