import os

# Tests run on the single real CPU device (the 512-device dry-run sets its
# own XLA_FLAGS inside launch/dryrun.py — NOT here, per the launch design).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
