"""Prime the benchmark cache: run the full experiment grid sequentially.

This feeds the paper-table harness (``benchmarks.tables``) via the
``results/bench_runs.json`` cache.  The ledger-producing scaling-law sweep
with per-cell checkpoint resume is ``repro.launch.sweep`` (+
``repro.launch.fit``); prefer it for new grids.

  PYTHONPATH=src python -m benchmarks.sweep            # everything missing
"""
from __future__ import annotations

import sys
import time

from benchmarks.common import LADDER, run_experiment


def grid():
    """The experiment grid, cheapest-first so partial sweeps are useful."""
    g = []
    # Table 4 / Figure 2: loss vs N for DP and DiLoCo M in {1,2,4}
    for arch in LADDER:
        for algo, m in [("dp", 1), ("diloco", 1), ("diloco", 2), ("diloco", 4)]:
            g.append(dict(arch=arch, algo=algo, m=m, tag="table4"))
    # Figure 4/5: batch-size robustness on t1 (fixed token budget; the
    # 2048 column is table4's cached default run)
    for b in (4096, 16384):
        for algo, m in [("dp", 1), ("diloco", 1), ("diloco", 2)]:
            g.append(dict(arch="tiny-t1", algo=algo, m=m, batch_tokens=b, tag="fig4"))
    # Figure 9: sync-cadence ablation on t1, M=2
    for h in (1, 5, 15):
        g.append(dict(arch="tiny-t1", algo="diloco", m=2, h=h, tag="fig9"))
    # Figure 8: outer-lr robustness across N (M=2): eta in {0.4, 0.7, 1.0}
    for arch in ("tiny-t0", "tiny-t1"):
        for eta in (0.4, 0.7, 1.0):
            g.append(dict(arch=arch, algo="diloco", m=2, eta=eta, tag="fig8"))
    # Figure 11: overtraining (lambda=4) on t0: dp + M=2
    for algo, m in [("dp", 1), ("diloco", 2)]:
        g.append(dict(arch="tiny-t0", algo=algo, m=m, budget_mult=20.0, tag="fig11"))
    return g


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for i, spec in enumerate(grid()):
        tag = spec.pop("tag")
        if only and tag != only:
            continue
        t0 = time.time()
        rec = run_experiment(**spec)
        print(
            f"[{i+1}] {tag} {spec} -> eval={rec['final_eval']:.4f} "
            f"({rec['steps']} steps, {time.time()-t0:.0f}s)",
            flush=True,
        )


if __name__ == "__main__":
    main()
