"""Generate EXPERIMENTS.md from the dry-run / perf / benchmark caches.

  PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import os

import numpy as np

HW_NOTE = """\
Hardware model (assignment constants): TPU v5e — 197 TFLOP/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI.  Meshes: single-pod (16,16)
("data","model") = 256 chips; multi-pod (2,16,16) ("pod","data","model")
= 512 chips.  The DiLoCo replica axis is bound to "pod"."""

METHOD_NOTE = """\
**Measurement methodology** (details in `src/repro/launch/dryrun.py`):

* Every cell's *deliverable* compile keeps the production scan-over-layers
  configuration: `jax.jit(train_step|serve_step).lower(...).compile()` on the
  target mesh, with `memory_analysis()` recorded.  XLA `cost_analysis()`
  counts `lax.scan` bodies once, so per-step flops/bytes/collectives are
  HLO-derived from two shallow **probe** compiles (1-group and 2-group
  unrolled stacks): `total = probe1 + (n_groups-1)*(probe2-probe1)`.
  Decode cells unroll fully and are measured directly.  SSD chunk loops stay
  scanned (they contain no collectives); their flops are added analytically.
* `cost_analysis()` on a partitioned module reports **per-device** numbers
  (verified empirically); the three roofline terms are per-device seconds.
* **Collective wire bytes** are parsed from the partitioned HLO with
  bandwidth-optimal ring models (all-reduce `2s(n-1)/n`, all-gather/all-to-all
  `s(n-1)/n`, reduce-scatter `s(n-1)`, permute `s`).  XLA:CPU upcasts bf16
  einsums to f32 *before* SPMD partitioning, so activation collectives print
  as f32; payloads are counted at bf16 (iteration 0 below audits this); the
  raw f32 count is kept in the JSON.
* The **memory term** uses HLO bytes clamped by an analytic TPU-HBM-traffic
  model (4x): CPU-XLA fusion is far weaker than TPU's, so raw CPU
  "bytes accessed" over-counts elementwise traffic that TPU fuses into
  matmul epilogues / the flash-attention kernel.
* MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (serve)."""


def _load(path):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def _advice(rec) -> str:
    rf = rec["roofline"]
    bn = rf["bottleneck"]
    kind = rec["kind"]
    if bn == "collective":
        if kind == "train":
            return "TP activation ARs dominate: sequence-shard the residual stream / raise per-pod batch; DiLoCo already confines this inside a pod."
        if kind == "decode":
            return "resharding between TP weights and seq-sharded KV: fuse the decode attention (flash-decode kernel) to psum only softmax partials."
        return "prefill TP ARs: overlap with compute (async collectives) or shard sequence."
    if bn == "memory":
        if kind == "decode":
            return "weight+KV streaming bound (expected for decode): raise batch per chip or quantize KV."
        return "HBM-bound: fuse elementwise chains (Pallas kernels) and keep activations bf16."
    return "MXU-bound (healthy): push per-device batch or overlap the residual collectives."


def dryrun_section(dry):
    lines = ["## §Dry-run — 40 cells x 2 production meshes\n",
             "Every (architecture x shape) cell lowers AND compiles on both the",
             "single-pod (256-chip) and multi-pod (512-chip) mesh. Train cells",
             "compile the fused DiLoCo `train_step` (inner AdamW + lax.cond outer",
             "sync — the cross-pod all-reduce is in the HLO); decode/prefill cells",
             "compile `serve_step`.  `long_500k` runs for the sub-quadratic archs",
             "(jamba, mamba2) per the assignment; the 8 pure-attention archs skip",
             "it (noted in DESIGN.md §5).\n",
             "| cell | mesh | ok | compile_s | args GB/dev | temps GB/dev | outer Δ bytes/dev (amortized /H) |",
             "|---|---|---|---|---|---|---|"]
    n_ok = 0
    for k in sorted(dry):
        v = dry[k]
        if not v.get("ok"):
            lines.append(f"| {k} | | FAILED: {v.get('error','')[:60]} | | | | |")
            continue
        n_ok += 1
        mem = v.get("memory", {})
        outer = v.get("outer_bytes_amortized_per_step")
        outer_s = f"{v.get('outer_bytes_per_dev',0)/1e6:.1f}MB ({outer/1e6:.1f}MB)" if outer else "—"
        lines.append(
            f"| {v['arch']} {v['shape']} | {v['mesh']} | ok | {v.get('compile_s','?')} "
            f"| {mem.get('argument_bytes',0)/1e9:.2f} | {mem.get('temp_bytes',0)/1e9:.2f} "
            f"| {outer_s} |"
        )
    lines.insert(1, (
        f"\n**{n_ok}/{len(dry)} compiles green** = 32 runnable cells x 2 meshes "
        "(of the 40 nominal cells, the 8 pure-full-attention archs skip "
        "`long_500k` per the assignment — see DESIGN.md §5).\n"
    ))
    return "\n".join(lines)


def roofline_section(dry):
    lines = ["\n## §Roofline — single-pod (256 chips), per device\n",
             "| cell | compute_s | memory_s | collective_s | bottleneck | MODEL_FLOPS | useful | MFU-bound | next move |",
             "|---|---|---|---|---|---|---|---|---|"]
    for k in sorted(dry):
        v = dry[k]
        if not v.get("ok") or v["mesh"] != "16x16" or not v.get("roofline_valid"):
            continue
        rf = v["roofline"]
        lines.append(
            f"| {v['arch']} {v['shape']} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
            f"| {rf['collective_s']:.3f} | **{rf['bottleneck']}** "
            f"| {rf['model_flops_total']:.2e} | {rf['useful_flops_ratio']:.2f} "
            f"| {rf['mfu_bound']:.3f} | {_advice(v)} |"
        )
    lines.append("""
Reading the table: `useful` = MODEL_FLOPS / (HLO flops x chips) — values
below ~0.75 indicate remat recompute (expected, ~4/6 for full remat),
dispatch-einsum overhead (MoE), or sharding that cannot use the model axis
(smollm's 15 heads, granite's 40 experts).  `MFU-bound` = MODEL_FLOPS /
(roofline step time x peak x chips) — the score this report optimizes.""")
    return "\n".join(lines)


def perf_section(perf):
    recs = {k: v for k, v in perf.items() if v.get("ok")}

    def g(key, field="collective_s"):
        r = recs.get(key)
        return r["roofline"][field] if r else float("nan")

    def mfu(key):
        return g(key, "mfu_bound")

    ds0, ds1 = "deepseek-67b|train_4k|16x16|it0-bf16count", "deepseek-67b|train_4k|16x16|it1-savecomm"
    ds2 = "deepseek-67b|train_4k|16x16|it2-zero1"
    gr0, gr1, gr2 = ("granite-moe-3b-a800m|train_4k|16x16|it0-bf16count",
                     "granite-moe-3b-a800m|train_4k|16x16|it1-group256",
                     "granite-moe-3b-a800m|train_4k|16x16|it2-group128")
    gr3 = "granite-moe-3b-a800m|train_4k|16x16|it3-capshard"
    jb0 = "jamba-1.5-large-398b|train_4k|2x16x16|it0-bf16count"
    jb1 = "jamba-1.5-large-398b|train_4k|2x16x16|it1-int8"
    jb2 = "jamba-1.5-large-398b|train_4k|2x16x16|it2-h100"

    def ob(key):
        r = recs.get(key)
        return r.get("outer_bytes_per_dev", float("nan")) if r else float("nan")

    lines = [f"""
## §Perf — hypothesis → change → measure → validate

Three hillclimb pairs (assignment: worst roofline fraction, most
collective-bound, most representative of the paper's technique).  The
**paper-faithful baseline** (Algorithm 1 exactly, default sharding) is the
first row of each block; beyond-paper optimizations follow and are recorded
separately.

### Pair A — deepseek-67b x train_4k (most collective-bound)

Baseline (paper-faithful, f32-counted): compute 14.81s / memory 0.16s /
collective 49.05s per device — collective-bound, MFU-bound 0.171.

| iteration | hypothesis (napkin) | result | verdict |
|---|---|---|---|
| it0 bf16-native payload counting | HLO dtype audit showed the dominant ARs are f32 `(16,4096,8192)` activation tensors — but XLA:CPU upcasts bf16 dots to f32 *before* partitioning; on TPU these are bf16, so wire bytes halve: 49.0 → ~24.5s | collective {g(ds0):.2f}s, MFU-bound {mfu(ds0):.3f} | **confirmed** (measurement fix, applied to all cells) |
| it1 remat_policy=save_comm (keep the 2 post-AR block outputs; bwd recompute skips fwd TP all-reduces) | 6 ARs/layer → 4: collective x0.67 ≈ 16.3s | collective {g(ds1):.2f}s, MFU-bound {mfu(ds1):.3f} | **partially confirmed**: −15.6% not −33% — XLA already deduplicated one of the two recompute ARs; memory cost +2 x 1GB/layer stored activations is acceptable per memory_analysis |
| it2 ZeRO-1 (params replicated over data, fp32 moments sharded) | weight AG traffic is ~0.26GB/layer-dev vs 4.3GB/layer-dev of activation ARs → <2% total; predicted no-op for THIS cell | collective {g(ds2):.2f}s, MFU-bound {mfu(ds2):.3f} | **confirmed no-op** (−0.6%): weight-gather traffic is dwarfed by activation ARs for this cell; kept as the memory-side option for models whose optimizer state does not fit replicated |

Net: MFU-bound 0.171 → {mfu(ds1):.3f} (+{(mfu(ds1)/0.171-1)*100:.0f}%). Remaining collective time is
the 4 bf16 residual-stream ARs/layer — the enumerated next step (not taken:
equal wire bytes) is Megatron-SP resharding; the real next win is overlapping
these ARs with the following matmul (XLA async collectives), which moves time
not bytes and so is invisible to this byte-derived roofline.

### Pair B — granite-moe-3b-a800m x train_4k (worst roofline fraction)

Baseline: useful-flops ratio 0.03 (!), MFU-bound 0.005 — the capacity-dispatch
einsums `(g,s,e,cap)` burn ~30x the expert flops at top-k=8, e=40, s=1024
(dispatch flops/token ∝ e·cap·d with cap ∝ s·k/e → ∝ s·k·d = 1024·8·1536).

| iteration | hypothesis (napkin) | result | verdict |
|---|---|---|---|
| it0 bf16 counting | as pair A | collective {g(gr0):.2f}s, compute {g(gr0,'compute_s'):.2f}s, MFU {mfu(gr0):.4f} | confirmed |
| it1 moe_group_size 1024→256 | dispatch flops ∝ group size: compute 3.8 → ~1.3s; collectives shrink with the dispatch tensors | compute {g(gr1,'compute_s'):.2f}s, collective {g(gr1):.2f}s, MFU {mfu(gr1):.4f} | compute **confirmed** (−40%, floor set by expert+attention matmuls); collectives **REFUTED** — byte-identical. Audit: the dominant AR is the `(g,e,cap,d)` expert-output partial sum whose size is `tokens·k·cf·d` — independent of group size. The refutation directly produced it3 |
| it2 moe_group_size →128 | another ~2x on dispatch; diminishing once expert matmuls dominate | compute {g(gr2,'compute_s'):.2f}s, collective {g(gr2):.2f}s, MFU {mfu(gr2):.4f} | confirmed (compute −11% more; collective unchanged as predicted by the it1 audit) |
| it3 capacity-dim sharding (`expert_cap→model`): keep expert matmuls local, defer the model-axis AR to the combined `(g,s,d)` output | AR bytes drop by `e·cap/tokens ≈ k·cf = 10x`: collective 10.1 → ~1.3s; granite becomes compute-bound | compute {g(gr3,'compute_s'):.2f}s, collective {g(gr3):.2f}s, MFU {mfu(gr3):.4f} | **confirmed** (7.6x collective cut, predicted ~10x; bottleneck flips to compute — granite is now MXU-bound and further wins come from the dispatch-flops side) |

The further structural fix (enumerated, costed, deferred): sort/gather token
routing (no capacity one-hots) — removes dispatch flops entirely but lowers
to dynamic-slice gathers whose GSPMD story needs ragged all-to-all;
group-size tuning + capacity-sharding capture most of the win within the
einsum formulation.

### Pair C — jamba-1.5-large-398b x train_4k multi-pod (the paper's regime)

The paper's currency is CROSS-POD bytes per step (Table 6).  398B params,
DiLoCo M=2 across pods, H=30.  The outer Δ all-reduce is measured from its
own compiled module (f32 deltas, per-device shard bytes).

| iteration | hypothesis | outer bytes/dev/sync | amortized /step (H) | verdict |
|---|---|---|---|---|
| it0 baseline H=30 | outer AR carries f32 Δ of the 398B model sharded over 256 chips/pod: ≈ 2·(796GB·2/256)·(1/2) ≈ 6.2GB | {ob(jb0)/1e9:.2f}GB | {ob(jb0)/30/1e9:.3f}GB | measured |
| it1 int8 outer compression (error feedback) | wire payload 1B+scales vs f32: /4 (HLO still shows the dequantized AR — payload accounting, kernel `delta_quant`) | {ob(jb1)/1e9:.2f}GB HLO / **{ob(jb1)/4/1e9:.2f}GB effective int8** | {ob(jb1)/4/30/1e9:.3f}GB | **confirmed** (quality cost bounded by EF telescoping test) |
| it2 H 30→100 | amortized bytes /3.33; paper Fig 9 shows larger models tolerate larger H | {ob(jb2)/1e9:.2f}GB | {ob(jb2)/100/1e9:.3f}GB | **confirmed** (exact 1/H) |

Combined it1+it2: cross-pod traffic/step drops {ob(jb0)/30/(ob(jb1)/4/100):.0f}x vs the paper-faithful
baseline — on the paper's own Table-6 bandwidth axis this moves the 95%-CU
requirement by the same factor. Inner-step collectives stay inside a pod by
construction (the pod axis only appears in the outer sync HLO).
"""]
    return "\n".join(lines)


def bench_section():
    bt = _load("results/bench_tables.json")
    if not bt:
        return "\n## §Paper-claims (benchmarks)\n\n(run `python -m benchmarks.run`)\n"
    lines = ["\n## §Paper-claims — benchmark-derived validations\n",
             "| artifact | derived checks |", "|---|---|"]
    for name, v in bt.items():
        lines.append(f"| {name} | `{json.dumps(v['derived'])}` |")
    lines.append("""
**What reproduces, and what needs the full-scale sweep** (honest summary):

* **Fitting machinery vs the paper's own data — exact.** Refitting the
  paper's published Table-4 losses recovers their Table-7 power-law
  coefficients to |Δα| ≤ 1e-4 and their Table-10 joint fit (A, α, β); all
  four §6.5 parametric forms land in the paper's Table-13 residual range,
  with holdout selection reproducing their protocol.  This validates every
  line of scaling-law code independent of our reduced-scale training runs.
* **Systems claims — quantitative.** The Table-6 compute-utilization
  simulator matches the paper's published bandwidths to one grid step
  (Llama3-405B DP@50%: ours 122.7 vs paper 126.5 Gbit/s) once the
  full-duplex/8-bit payload convention is identified; H-scaling is exactly
  1/H; the Appendix-A wall-clock model reproduces Figures 6/12 structure
  (DiLoCo faster on every network tier, most on low-bandwidth).
* **Loss-ordering claims — directional only at container scale.** Findings
  1-3 concern 0.1-2% eval-loss gaps that emerge at ≥35M params with
  per-algorithm lr/batch tuning; our 0.1-0.8M-param CPU ladder with one
  shared lr recipe shows DP ≤ DiLoCo throughout (gap ~0.3-0.6%, shrinking
  in absolute terms with N), extrapolation residuals ≤ 0.006, and the
  optimal-η-constant-across-N check passes.  The harness runs the paper's
  exact recipe unchanged at full scale (`repro.launch.train --arch
  chinchilla-35m ... --arch chinchilla-10b`).""")
    return "\n".join(lines)


def main():
    dry = _load("results/dryrun.json")
    perf = _load("results/perf.json")
    doc = [
        "# EXPERIMENTS — DiLoCo scaling-laws reproduction\n",
        HW_NOTE, "", METHOD_NOTE, "",
        dryrun_section(dry),
        roofline_section(dry),
        perf_section(perf),
        bench_section(),
    ]
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(doc))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
