# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one entry per paper table/figure.

``us_per_call`` reports the harness cost of producing that artifact
(training benches amortize via the run cache: the cost of one training
step is reported instead, which is the number a cluster operator cares
about).  ``derived`` carries the paper-claim validation for that table.

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run table4     # one table
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp


def _kernel_microbench():
    """us/call of each Pallas kernel (interpret mode — correctness path;
    on-TPU timing requires hardware)."""
    from repro.kernels.delta_quant.ops import quantize
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.fused_adamw.ops import fused_adamw
    from repro.kernels.outer_nesterov.ops import outer_nesterov
    from repro.kernels.ssd_scan.ops import ssd_chunk_scan

    key = jax.random.PRNGKey(0)
    rows = []

    def timeit(name, fn, *args, reps=3):
        fn(*args)  # warm
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        rows.append({"name": f"kernel_{name}", "us": (time.time() - t0) / reps * 1e6,
                     "derived": "interpret-mode"})

    q = jax.random.normal(key, (8, 256, 64))
    k = jax.random.normal(key, (4, 256, 64))
    timeit("flash_attention", lambda a, b, c: flash_attention(a, b, c, True), q, k, k)
    p = jax.random.normal(key, (1 << 16,))
    m = jnp.zeros(1 << 16)
    timeit("fused_adamw", lambda a, b, c, d: fused_adamw(
        a, b, c, d, lr=1e-3, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
        bc1=0.1, bc2=0.01), p, p, m, m)
    d4 = jax.random.normal(key, (4, 1 << 14))
    g = jax.random.normal(key, (1 << 14,))
    timeit("outer_nesterov", lambda a, b, c: outer_nesterov(a, b, c, lr=0.7, mu=0.9),
           g, d4, jnp.zeros(1 << 14))
    timeit("delta_quant", quantize, jax.random.normal(key, (1 << 16,)))
    x = jax.random.normal(key, (1, 256, 8, 16))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 256, 8)))
    A = -jnp.ones((8,))
    B = jax.random.normal(key, (1, 256, 1, 16))
    timeit("ssd_scan", lambda *a: ssd_chunk_scan(*a, chunk=64), x, dt, A, B, B)
    return rows


def main() -> None:
    from benchmarks import tables

    only = sys.argv[1] if len(sys.argv) > 1 else None
    artifacts = {
        "table4_loss_vs_scale": tables.table4,
        "table5_extrapolation": tables.table5,
        "table6_compute_utilization": tables.table6,
        "table7_power_laws": tables.table7,
        "table10_joint_fit": tables.table10,
        "table11_residuals": tables.table11,
        "table13_parametric_forms": tables.table13,
        "fig4_batch_size": tables.fig4,
        "fig6_wallclock": tables.fig6,
        "fig8_outer_lr": tables.fig8,
        "fig9_sync_cadence": tables.fig9,
        "fig11_overtraining": tables.fig11,
    }
    print("name,us_per_call,derived")
    results = {}
    for name, fn in artifacts.items():
        if only and only not in name:
            continue
        t0 = time.time()
        rows, derived = fn()
        us = (time.time() - t0) * 1e6
        results[name] = {"rows": rows, "derived": derived}
        print(f"{name},{us:.0f},{json.dumps(derived)}")
    if only is None or "kernel" in (only or ""):
        for r in _kernel_microbench():
            print(f"{r['name']},{r['us']:.0f},{r['derived']}")
    import os

    os.makedirs("results", exist_ok=True)
    with open("results/bench_tables.json", "w") as f:
        json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
