"""Shared benchmark infrastructure: cached training runs on the CPU ladder.

Every experiment is a pure function of its config, cached in
``results/bench_runs.json`` — re-running ``benchmarks.run`` reuses finished
runs, so the expensive sweeps happen once (and can be primed in the
background via ``python -m benchmarks.sweep``).

Scale notes (documented deviation, DESIGN.md §9): the container is one CPU
core, so the ladder is ~0.1-0.8M params with a reduced-but-CONSTANT token
budget rule D = BUDGET_MULT * N (the scaling-law methodology needs a
consistent budget rule across N, not a particular constant), seq_len 128,
vocab 256 synthetic Markov corpus.  The same harness runs the paper's exact
recipe unchanged at full scale (see repro.launch.train).
"""
from __future__ import annotations

import hashlib
import json
import os
import time

import jax
import numpy as np

from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core.diloco import make_trainer
from repro.core.superstep import SuperstepEngine
from repro.data import SyntheticLM
from repro.models import build_model

CACHE = os.environ.get("REPRO_BENCH_CACHE", "results/bench_runs.json")
BUDGET_MULT = 5.0      # reduced-Chinchilla D = 5N (paper: 20N; constant rule is what matters)
SEQ_LEN = 128
LADDER = ("tiny-t0", "tiny-t1", "tiny-t2")
# optimal batch grows with model size (paper Finding 3); per-size defaults
DEFAULT_BATCH = {"tiny-t0": 2048, "tiny-t1": 2048, "tiny-t2": 8192}

# fixed lr recipe per width (the paper sweeps lr; one CPU core cannot — a
# 1/width rule is the standard mu-P-flavored default)
def default_lr(cfg) -> float:
    return 3e-3 * (64 / cfg.d_model) ** 0.5


def _key(spec: dict) -> str:
    return hashlib.sha1(json.dumps(spec, sort_keys=True).encode()).hexdigest()[:16]


def _load() -> dict:
    if os.path.exists(CACHE):
        with open(CACHE) as f:
            return json.load(f)
    return {}


def _save(cache: dict):
    os.makedirs(os.path.dirname(CACHE) or ".", exist_ok=True)
    with open(CACHE, "w") as f:
        json.dump(cache, f, indent=1)


def run_experiment(
    *,
    arch: str,
    algo: str = "diloco",          # dp | diloco
    m: int = 1,
    h: int = 15,
    batch_tokens: int = 0,          # 0 -> per-size default (grows with N, paper Fig 4)
    lr: float = 0.0,               # 0 -> default rule
    eta: float = 0.7,
    budget_mult: float = BUDGET_MULT,
    seed: int = 0,
    eval_batches: int = 8,
    force: bool = False,
    engine: str = "superstep",      # superstep | per-step (see core.superstep)
) -> dict:
    """Train to the budget; return {final_eval, n_params, steps, s_per_step}."""
    cfg = get_config(arch)
    model = build_model(cfg)
    n_params = model.param_count()
    batch_tokens = batch_tokens or DEFAULT_BATCH.get(arch, 2048)
    lr = lr or default_lr(cfg)
    steps = max(int(budget_mult * n_params / batch_tokens), 20)
    spec = dict(arch=arch, algo=algo, m=m, h=h, batch_tokens=batch_tokens,
                lr=round(lr, 8), eta=eta, budget_mult=budget_mult, seed=seed,
                seq=SEQ_LEN, engine=engine, v=3)
    key = _key(spec)
    cache = _load()
    if key in cache and not force:
        return cache[key]
    if os.environ.get("REPRO_BENCH_NO_TRAIN"):
        # assemble-only mode (final report under a deadline): missing runs
        # surface as NaN rows instead of training synchronously
        return {"spec": spec, "final_eval": float("nan"), "final_eval_sem": float("nan"),
                "final_train": float("nan"), "n_params": n_params, "steps": steps,
                "s_per_step": float("nan"), "loss_curve": [], "missing": True}

    tcfg = TrainConfig(global_batch_tokens=batch_tokens, seq_len=SEQ_LEN, steps=steps)
    dcfg = DiLoCoConfig(
        num_replicas=m if algo == "diloco" else 1,
        sync_every=h, outer_lr=eta, data_parallel=(algo == "dp"),
    )
    ocfg = OptimizerConfig(peak_lr=lr, warmup_steps=min(100, steps // 10 + 1))
    trainer = make_trainer(model, dcfg, ocfg, tcfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=SEQ_LEN, seed=1234)

    seqs_per_replica = max(1, batch_tokens // SEQ_LEN // trainer.M)
    state = trainer.init_state(jax.random.PRNGKey(seed))
    eval_step = jax.jit(trainer.eval_step)
    t0 = time.time()
    if engine == "superstep":
        # one compiled, donated executable per outer round; one host sync
        # per round (the sweep's hot path — see repro.core.superstep)
        eng = SuperstepEngine(trainer, data, seqs_per_replica)
        state, mets = eng.run(state, steps)
        losses = [float(x) for x in np.asarray(mets["loss"])]
    else:
        inner = trainer.jit_inner_step()
        outer = trainer.jit_outer_sync()
        losses = []
        for t in range(steps):
            batch = data.global_batch(t, trainer.M, seqs_per_replica)
            state, metrics = inner(state, batch)
            if algo == "diloco" and (t + 1) % h == 0:
                state = outer(state)
            losses.append(float(metrics["loss"]))
    if algo == "diloco" and steps % h != 0:
        state = trainer.jit_outer_sync()(state)  # final sync so eval sees all progress
    dt = time.time() - t0

    evals = [
        float(eval_step(state, data.batch(50_000 + i, 0, 1, 16, eval=True)))
        for i in range(eval_batches)
    ]
    rec = {
        "spec": spec,
        "final_eval": float(np.mean(evals)),
        "final_eval_sem": float(np.std(evals) / np.sqrt(len(evals))),
        "final_train": float(np.mean(losses[-10:])),
        "n_params": n_params,
        "steps": steps,
        "s_per_step": dt / steps,
        "loss_curve": losses[:: max(1, steps // 100)],
    }
    cache = _load()
    cache[key] = rec
    _save(cache)
    return rec


def ladder_sizes():
    return {a: build_model(get_config(a)).param_count() for a in LADDER}
