"""One assembler per paper table/figure.  Each returns (rows, derived-notes)
and pulls training results from the benchmark cache (benchmarks.common)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import LADDER, ladder_sizes, run_experiment
from repro.core import compute_util as cu
from repro.core import scaling_laws as sl
from repro.core import wallclock as wc

ALGOS = [("dp", 1), ("diloco", 1), ("diloco", 2), ("diloco", 4)]


def _algo_name(algo, m):
    return "Data-Parallel" if algo == "dp" else f"DiLoCo, M={m}"


# ---------------------------------------------------------------------------
# Table 4 / Figure 2: eval loss vs N for each algorithm
# ---------------------------------------------------------------------------


def table4():
    sizes = ladder_sizes()
    rows = []
    for arch in LADDER:
        rec_dp = run_experiment(arch=arch, algo="dp", m=1)
        for algo, m in ALGOS:
            rec = run_experiment(arch=arch, algo=algo, m=m)
            rows.append({
                "arch": arch, "n_params": sizes[arch],
                "algo": _algo_name(algo, m),
                "eval": rec["final_eval"], "sem": rec["final_eval_sem"],
                "pct_vs_dp": 100 * (rec["final_eval"] / rec_dp["final_eval"] - 1),
            })
    # Finding 1: relative gap of DiLoCo M>1 vs DP shrinks with N
    derived = {}
    for m in (2, 4):
        gaps = [r["pct_vs_dp"] for r in rows if r["algo"] == f"DiLoCo, M={m}"]
        derived[f"gap_shrinks_with_N_M{m}"] = bool(gaps[-1] <= gaps[0])
    m1 = [r["pct_vs_dp"] for r in rows if r["algo"] == "DiLoCo, M=1"]
    derived["diloco_m1_beats_dp_frac"] = float(np.mean([g <= 0 for g in m1]))
    return rows, derived


# ---------------------------------------------------------------------------
# Tables 7/10: power-law fits on OUR ladder + validation on PAPER data
# ---------------------------------------------------------------------------


def table7():
    sizes = ladder_sizes()
    n = np.array([sizes[a] for a in LADDER], float)
    rows = []
    for algo, m in ALGOS:
        y = [run_experiment(arch=a, algo=algo, m=m)["final_eval"] for a in LADDER]
        A, alpha = sl.fit_power_law(n, y)
        rows.append({"algo": _algo_name(algo, m), "A": A, "alpha": alpha,
                     "source": "ours(reduced)"})
    for algo, (A_ref, a_ref) in sl.PAPER_TABLE7_FITS.items():
        A, alpha = sl.fit_power_law(sl.PAPER_MODEL_SIZES, sl.PAPER_TABLE4_LOSS[algo])
        rows.append({"algo": algo, "A": A, "alpha": alpha,
                     "paper_A": A_ref, "paper_alpha": a_ref, "source": "paper-data-refit"})
    derived = {"paper_refit_max_alpha_err": max(
        abs(r["alpha"] - r["paper_alpha"]) for r in rows if "paper_alpha" in r)}
    return rows, derived


def table10():
    sizes = ladder_sizes()
    n, m_, y = [], [], []
    for arch in LADDER:
        for algo, m in ALGOS:
            if algo != "diloco":
                continue
            n.append(sizes[arch])
            m_.append(m)
            y.append(run_experiment(arch=arch, algo=algo, m=m)["final_eval"])
    A, alpha, beta = sl.fit_joint_power_law(n, m_, y)
    rows = [{"fit": "L(N,M)=A N^a M^b", "A": A, "alpha": alpha, "beta": beta,
             "source": "ours(reduced)"}]
    # paper-data refit
    pn, pm, py = [], [], []
    for m in (1, 2, 4, 8):
        pn.extend(sl.PAPER_MODEL_SIZES)
        pm.extend([m] * 7)
        py.extend(sl.PAPER_TABLE4_LOSS[f"diloco_m{m}"])
    A2, a2, b2 = sl.fit_joint_power_law(pn, pm, py)
    rows.append({"fit": "L(N,M)=A N^a M^b", "A": A2, "alpha": a2, "beta": b2,
                 "paper": sl.PAPER_TABLE10_JOINT["L"], "source": "paper-data-refit"})
    derived = {"beta_positive_ours": bool(beta > 0),
               "paper_refit_matches": bool(abs(a2 - (-0.0985)) < 4e-3 and abs(b2 - 0.0116) < 4e-3)}
    return rows, derived


# ---------------------------------------------------------------------------
# Table 11: leave-largest-out residuals, independent vs joint fits
# ---------------------------------------------------------------------------


def table11():
    sizes = ladder_sizes()
    fit_archs, held = LADDER[:-1], LADDER[-1]
    n_fit = np.array([sizes[a] for a in fit_archs], float)
    n_held = sizes[held]
    rows = []
    for m in (1, 2, 4):
        y_fit = [run_experiment(arch=a, algo="diloco", m=m)["final_eval"] for a in fit_archs]
        y_true = run_experiment(arch=held, algo="diloco", m=m)["final_eval"]
        A, alpha = sl.fit_power_law(n_fit, y_fit)
        res_ind = sl.residual([y_true], [A * n_held ** alpha])
        rows.append({"M": m, "fit": "independent", "res_L": res_ind})
    # joint
    jn, jm, jy = [], [], []
    for m in (1, 2, 4):
        for a in fit_archs:
            jn.append(sizes[a])
            jm.append(m)
            jy.append(run_experiment(arch=a, algo="diloco", m=m)["final_eval"])
    A, alpha, beta = sl.fit_joint_power_law(jn, jm, jy)
    for m in (1, 2, 4):
        y_true = run_experiment(arch=held, algo="diloco", m=m)["final_eval"]
        pred = sl.predict_joint(A, alpha, beta, n_held, m)
        rows.append({"M": m, "fit": "joint", "res_L": sl.residual([y_true], [pred])})
    ind = np.mean([r["res_L"] for r in rows if r["fit"] == "independent"])
    joint = np.mean([r["res_L"] for r in rows if r["fit"] == "joint"])
    return rows, {"avg_res_independent": float(ind), "avg_res_joint": float(joint)}


# ---------------------------------------------------------------------------
# Table 13: parametric forms on the PAPER's published losses
# ---------------------------------------------------------------------------


def table13():
    n, m, y = [], [], []
    for mm in (1, 2, 4, 8):
        n.extend(sl.PAPER_MODEL_SIZES)
        m.extend([mm] * 7)
        y.extend(sl.PAPER_TABLE4_LOSS[f"diloco_m{mm}"])
    n, m, y = map(np.asarray, (n, m, y))
    holdout = n >= 2.4e9
    rows = []
    for form in sl.PARAMETRIC_FORMS:
        _, obj, res = sl.fit_parametric(form, n, m, y, restarts=48, holdout_mask=holdout)
        rows.append({"form": form, "holdout_residual": res, "train_obj": obj})
    best = min(rows, key=lambda r: r["holdout_residual"])
    return rows, {"best_form": best["form"], "paper_best": "AN^(a+bM)+C",
                  "all_forms_in_paper_range": bool(all(r["holdout_residual"] < 0.02 for r in rows))}


# ---------------------------------------------------------------------------
# Table 6: compute-utilization simulation (+ beyond-paper int8 row)
# ---------------------------------------------------------------------------


def table6():
    rows = cu.table6()
    comp = cu.table6(compression_ratio=2.0)
    for r in comp:
        r["method"] += " +int8"
    rows += [r for r in comp if "H=100" in r["method"]]
    # headline: bandwidth reduction factors vs Data-Parallel at CU=80%
    chin = {r["method"]: r["gbits"] for r in rows if r["model"] == "Chinchilla-10B"}
    derived = {
        "reduction_H100_at80": chin["Data-Parallel"][1] / chin["DiLoCo, H=100"][1],
        "reduction_H100_int8_at80": chin["Data-Parallel"][1] / chin["DiLoCo, H=100 +int8"][1],
    }
    return rows, derived


# ---------------------------------------------------------------------------
# Figure 6/12: idealized wall-clock
# ---------------------------------------------------------------------------


def fig6():
    rows = []
    for net in (wc.LOW, wc.MEDIUM, wc.HIGH):
        for n in (0.35e9, 1.3e9, 2.4e9, 10e9):
            for algo, m in [("dp", 1), ("diloco", 2), ("diloco", 4)]:
                t = wc.train_time(n, 20 * n, 2**21, algorithm=algo, m_replicas=m,
                                  sync_every=30, cross_net=net)
                rows.append({"net": net.name, "N": n, "algo": _algo_name(algo, m),
                             **{k: t[k] for k in ("compute_s", "comm_s", "total_s")}})
    # DiLoCo faster than DP on the low-bandwidth network at every size
    low = [r for r in rows if r["net"] == "low"]
    by_n = {}
    for r in low:
        by_n.setdefault(r["N"], {})[r["algo"]] = r["total_s"]
    derived = {"diloco_m2_faster_low_bw": bool(all(
        v["DiLoCo, M=2"] < v["Data-Parallel"] for v in by_n.values()))}
    return rows, derived


# ---------------------------------------------------------------------------
# Figures 4/5: batch-size robustness;  Figure 9: H;  Figure 8: eta;  Fig 11
# ---------------------------------------------------------------------------


def fig4():
    rows = []
    for b in (2048, 4096, 16384):
        for algo, m in [("dp", 1), ("diloco", 1), ("diloco", 2)]:
            rec = run_experiment(arch="tiny-t1", algo=algo, m=m, batch_tokens=b)
            rows.append({"batch_tokens": b, "algo": _algo_name(algo, m),
                         "eval": rec["final_eval"]})
    # degradation from smallest to largest batch
    def degr(name):
        e = {r["batch_tokens"]: r["eval"] for r in rows if r["algo"] == name}
        return e[16384] - e[2048]
    derived = {"dp_degradation": degr("Data-Parallel"),
               "diloco_m2_degradation": degr("DiLoCo, M=2"),
               "diloco_more_batch_tolerant":
                   bool(degr("DiLoCo, M=2") < degr("Data-Parallel"))}
    return rows, derived


def fig9():
    rows = []
    for h in (1, 5, 15):
        rec = run_experiment(arch="tiny-t1", algo="diloco", m=2, h=h)
        rows.append({"H": h, "eval": rec["final_eval"]})
    return rows, {"h1_worst_or_close": bool(
        rows[0]["eval"] >= min(r["eval"] for r in rows) - 0.002)}


def fig8():
    rows = []
    for arch in ("tiny-t0", "tiny-t1"):
        best = None
        for eta in (0.4, 0.7, 1.0):
            rec = run_experiment(arch=arch, algo="diloco", m=2, eta=eta)
            rows.append({"arch": arch, "eta": eta, "eval": rec["final_eval"]})
            if best is None or rec["final_eval"] < best[1]:
                best = (eta, rec["final_eval"])
        rows.append({"arch": arch, "eta": best[0], "eval": best[1], "best": True})
    bests = [r["eta"] for r in rows if r.get("best")]
    return rows, {"optimal_eta_constant_across_N": bool(len(set(bests)) == 1)}


def fig11():
    rows = []
    for algo, m in [("dp", 1), ("diloco", 2)]:
        for mult, lam in ((5.0, 1), (20.0, 4)):
            rec = run_experiment(arch="tiny-t0", algo=algo, m=m, budget_mult=mult)
            rows.append({"algo": _algo_name(algo, m), "overtrain": lam,
                         "eval": rec["final_eval"]})
    # overtraining helps both algorithms; ordering preserved
    e = {(r["algo"], r["overtrain"]): r["eval"] for r in rows}
    derived = {
        "overtraining_helps_dp": bool(e[("Data-Parallel", 4)] < e[("Data-Parallel", 1)]),
        "overtraining_helps_diloco": bool(e[("DiLoCo, M=2", 4)] < e[("DiLoCo, M=2", 1)]),
    }
    return rows, derived


# ---------------------------------------------------------------------------
# Table 5 analog: extrapolate fits to the next size up and validate
# ---------------------------------------------------------------------------


def table5():
    """Fit on t0/t1, predict t2, then train t2 and compare (the paper's
    4B/10B extrapolation protocol at ladder scale)."""
    sizes = ladder_sizes()
    rows = []
    for algo, m in ALGOS:
        y = [run_experiment(arch=a, algo=algo, m=m)["final_eval"] for a in LADDER[:-1]]
        A, alpha = sl.fit_power_law([sizes[a] for a in LADDER[:-1]], y)
        pred = float(A * sizes[LADDER[-1]] ** alpha)
        true = run_experiment(arch=LADDER[-1], algo=algo, m=m)["final_eval"]
        rows.append({"algo": _algo_name(algo, m), "predicted": pred, "actual": true,
                     "residual": sl.residual([true], [pred])})
    return rows, {"max_extrapolation_residual": max(r["residual"] for r in rows)}
