"""Sweep-throughput benchmark: sequential vs shared-executable vs stacked.

Times three executions of the same grid (fresh ledger each time) and pins
the result in ``results/BENCH_sweep.json``:

* ``sequential`` — the pre-PR-4 behavior: every cell runs alone AND builds
  its own executables (``jitcache.sharing(False)``), so each cell pays a
  full trace + XLA compile even when only a scalar hyperparameter differs.
* ``shared`` — cells still run one at a time, but executables are cached
  process-wide by static shape signature: each distinct cell *shape*
  compiles exactly once (asserted via the compile counter below).
* ``stacked`` — ``plan_groups`` + ``CellBatchEngine``: shape-compatible
  cells run as ONE vmapped donated executable; per-cell ledger records are
  bitwise-identical to the sequential path (asserted under ``--check``).

Compile counting uses ``jax.monitoring``'s backend-compile duration events
— actual XLA compilations, not Python-side cache misses.  The persistent
compilation cache is deliberately NOT enabled here (a warm disk cache
would hide exactly the cost being measured); a separate ``warm_rerun``
phase measures it explicitly: the same grid re-run in a subprocess against
the cache directory the first subprocess populated.

  PYTHONPATH=src python -m benchmarks.bench_sweep                # full
  PYTHONPATH=src python -m benchmarks.bench_sweep --check \\
      --grids smoke-stack --out results/BENCH_sweep_smoke.json   # CI smoke

Reading ``BENCH_sweep.json``: one row per (grid, path) with wall-clock
``time_to_ledger_s`` (expand -> every record durable), ``cells_per_s``,
and ``backend_compiles``; ``speedup_stacked`` / ``speedup_shared`` compare
against the sequential row.  ``stack_groups`` lists the planner's
partition so a regression in grouping is visible in the artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import jax
import jax.monitoring

from repro.configs import get_sweep
from repro.core import jitcache
from repro.launch.sweep import (
    cell_id,
    expand_grid,
    plan_groups,
    read_ledger,
    run_sweep,
)

# ladder-lite: the ladder recipe cut to CPU-bench size, with a seed axis so
# every one of the four sync modes forms stackable pairs.
LADDER_LITE = (
    get_sweep("ladder").replace(
        name="ladder-lite",
        archs=("tiny-t0", "tiny-t1"),
        modes=("dp", "diloco", "int8", "streaming"),
        replicas=(1, 2),
        sync_every=(4,),
        batch_tokens=(1024,),
        seq_len=64,
        steps=8,
        seeds=(0, 1),
        eval_batches=2,
        eval_seqs=8,
        checkpoint_every=0,
    )
)

_COMPILES = [0]


def _count_compiles(event: str, duration: float, **kw) -> None:
    if event == "/jax/core/compile/backend_compile_duration":
        _COMPILES[0] += 1


jax.monitoring.register_event_duration_secs_listener(_count_compiles)


def _grid(name: str):
    return LADDER_LITE if name == "ladder-lite" else get_sweep(name)


def _run(sweep, workdir: str, *, stack: bool, share: bool) -> dict:
    ledger = os.path.join(workdir, f"SWEEP_{sweep.name}.jsonl")
    if os.path.exists(ledger):
        os.remove(ledger)
    jitcache.clear()  # phases must not inherit each other's executables
    c0, t0 = _COMPILES[0], time.perf_counter()
    with jitcache.sharing(share):
        run_sweep(sweep, ledger, "", quiet=True, stack=stack)
    dt = time.perf_counter() - t0
    records = read_ledger(ledger)
    return {
        "n_cells": len(records),
        "time_to_ledger_s": dt,
        "cells_per_s": len(records) / dt,
        "backend_compiles": _COMPILES[0] - c0,
        "round_builds": jitcache.builds_by_kind().get("superstep", 0),
        "ledger": records,
    }


def _strip(row: dict) -> dict:
    return {k: v for k, v in row.items() if k != "ledger"}


def _ledger_equal(a: dict, b: dict, *, skip=("runtime_s",)) -> list:
    """Field-wise comparison of two ledgers; returns mismatch descriptions."""
    bad = []
    if set(a) != set(b):
        return [f"cell sets differ: {sorted(set(a) ^ set(b))}"]
    for cid in a:
        for key in a[cid]:
            if key in skip:
                continue
            if a[cid][key] != b[cid].get(key):
                bad.append(f"{cid}.{key}: {a[cid][key]!r} != {b[cid].get(key)!r}")
    return bad


def bench_grid(name: str, workdir: str, *, check: bool) -> dict:
    sweep = _grid(name)
    cells = expand_grid(sweep)
    plan = plan_groups(cells)
    groups = sorted(
        {id(g): [cell_id(s) for s in g] for g in plan.values()}.values(),
        key=len, reverse=True,
    )
    shapes = {
        (s["arch"], s["mode"], s["m"], s["h"], s["batch_tokens"],
         s["seq_len"], s["steps"], s["nesterov"], s["streaming_fragments"])
        for s in cells
    }
    distinct_shapes = len(shapes)
    # expected superstep-round executables on the shared path: one per
    # distinct shape per round-length variant (a non-H-aligned step count
    # adds a shorter tail round)
    expected_rounds = sum(
        1 if s["steps"] % s["h"] == 0 else 2
        for s in ({"steps": k[6], "h": max(k[3], 1)} for k in shapes)
    )
    print(f"--- grid {name}: {len(cells)} cells, {distinct_shapes} distinct "
          f"shapes, {len(groups)} stacked groups "
          f"{[len(g) for g in groups]}")

    seq = _run(sweep, workdir, stack=False, share=False)
    shared = _run(sweep, workdir, stack=False, share=True)
    stacked = _run(sweep, workdir, stack=True, share=True)

    out = {
        "grid": name,
        "n_cells": len(cells),
        "modes": sorted({s["mode"] for s in cells}),  # sync strategies covered
        "distinct_shapes": distinct_shapes,
        "expected_round_builds": expected_rounds,
        "stack_groups": [len(g) for g in groups],
        "sequential": _strip(seq),
        "shared": _strip(shared),
        "stacked": _strip(stacked),
        "speedup_shared": shared["cells_per_s"] / seq["cells_per_s"],
        "speedup_stacked": stacked["cells_per_s"] / seq["cells_per_s"],
        "ledger_identical_stacked_vs_sequential":
            not _ledger_equal(seq["ledger"], stacked["ledger"]),
    }
    for path in ("sequential", "shared", "stacked"):
        r = out[path]
        print(f"{path:11s} {r['n_cells']} cells in "
              f"{r['time_to_ledger_s']:6.1f}s = {r['cells_per_s']:.3f} "
              f"cells/s, {r['backend_compiles']} backend compiles, "
              f"{r['round_builds']} round executables")
    print(f"speedups vs sequential: shared {out['speedup_shared']:.2f}x, "
          f"stacked {out['speedup_stacked']:.2f}x")

    if check:
        mism = _ledger_equal(seq["ledger"], stacked["ledger"])
        assert not mism, "stacked ledger != sequential ledger:\n" + "\n".join(mism)
        mism = _ledger_equal(seq["ledger"], shared["ledger"])
        assert not mism, "shared ledger != sequential ledger:\n" + "\n".join(mism)
        assert stacked["cells_per_s"] >= seq["cells_per_s"], (
            f"stacked path slower than sequential: "
            f"{stacked['cells_per_s']:.3f} < {seq['cells_per_s']:.3f} cells/s")
        # shared path: each distinct cell shape compiles its round
        # executable(s) EXACTLY once, regardless of how many cells share
        # the shape
        assert shared["round_builds"] == expected_rounds, (
            f"shared path built {shared['round_builds']} round executables, "
            f"expected exactly {expected_rounds} (one per distinct shape "
            "and round-length variant)")
        assert shared["backend_compiles"] <= seq["backend_compiles"], (
            shared["backend_compiles"], seq["backend_compiles"])
        if len(cells) > distinct_shapes:
            assert shared["backend_compiles"] < seq["backend_compiles"], (
                "shape-repeating grid did not reuse executables: "
                f"{shared['backend_compiles']} vs {seq['backend_compiles']}")
    return out


def bench_warm_cache(name: str, workdir: str) -> dict:
    """Persistent-compilation-cache phase: run the grid in a subprocess
    with a cold ``--xla-cache`` dir, then re-run (fresh ledger, warm
    cache); the second run should skip backend compilation entirely."""
    import subprocess
    import sys

    cache_dir = os.path.join(workdir, "xla_cache")
    times = {}
    for phase in ("cold", "warm"):
        ledger = os.path.join(workdir, f"SWEEP_cachephase_{phase}.jsonl")
        env = dict(os.environ, REPRO_XLA_CACHE_DIR=cache_dir,
                   PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
        t0 = time.perf_counter()
        subprocess.run(
            [sys.executable, "-m", "repro.launch.sweep", "--grid", name,
             "--ledger", ledger, "--checkpoint-root", "none"],
            check=True, env=env, capture_output=True,
        )
        times[phase] = time.perf_counter() - t0
    return {
        "grid": name,
        "cache_dir_entries": len(os.listdir(cache_dir)),
        "cold_s": times["cold"],
        "warm_s": times["warm"],
        "speedup_warm": times["cold"] / times["warm"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grids", default="smoke-stack,smoke,ladder-lite",
                    help="comma-separated grid names (smoke-stack / smoke / "
                         "ladder-lite / any named SweepSpec)")
    ap.add_argument("--check", action="store_true",
                    help="assert stacked >= sequential cells/s, "
                         "shared-path compile reuse, and bitwise-identical "
                         "ledgers (CI smoke)")
    ap.add_argument("--warm-cache-grid", default="",
                    help="also measure a cold-vs-warm persistent-cache "
                         "re-run of this grid (subprocesses)")
    ap.add_argument("--out", default="results/BENCH_sweep.json")
    args = ap.parse_args()

    workdir = tempfile.mkdtemp(prefix="bench_sweep_")
    try:
        rows = [
            bench_grid(name.strip(), workdir, check=args.check)
            for name in args.grids.split(",") if name.strip()
        ]
        warm = None
        if args.warm_cache_grid:
            warm = bench_warm_cache(args.warm_cache_grid, workdir)
            print(f"persistent cache: cold {warm['cold_s']:.1f}s -> warm "
                  f"{warm['warm_s']:.1f}s ({warm['speedup_warm']:.2f}x, "
                  f"{warm['cache_dir_entries']} cache entries)")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    out = {
        "device": jax.devices()[0].platform,
        "results": rows,
        "warm_cache": warm,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
