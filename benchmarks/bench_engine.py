"""Hot-path benchmark: per-step loops vs the compiled superstep engine.

Times three executions of identical work (same model, data, sync schedule):

* ``seed_loop`` — the pre-engine baseline this repo shipped with: one
  ``jax.jit`` dispatch per inner step with NO buffer donation (the state is
  re-materialized every call), host-built batches, a blocking
  ``float(loss)`` host sync every step, and (for streaming) the eager
  per-call Python tree-flatten fragment sync.
* ``per_step`` — the improved per-step engine (``--engine per-step``):
  donated entry points and jit-cached fragment syncs, but still one
  dispatch + one host sync per inner step.
* ``superstep`` — one compiled, donated executable per outer round with
  on-device batch generation and ONE host sync per round
  (``repro.core.superstep``).

Methodology: the headline config is deliberately OVERHEAD-DOMINATED (tiny
batch on the tiny-t1 ladder model) because that is the regime the engine
targets — on production accelerators an inner step is milliseconds, so
per-step Python dispatch, host batch assembly, and host syncs are the wall
clock.  One CPU core only reaches that regime with a small per-step token
count; pass ``--batch-tokens/--seq-len`` to probe compute-bound regimes
(where all three engines converge on the same hardware floor).  Each engine
gets one warmup window (compile + first round), then the best of
``--windows`` timed windows is reported, which suppresses noise from
background load on shared machines.

  PYTHONPATH=src python -m benchmarks.bench_engine                 # full run
  PYTHONPATH=src python -m benchmarks.bench_engine --steps 20      # CI smoke

Reading ``BENCH_engine.json``: one row per sync mode;
``speedup_vs_seed`` = superstep vs the seed loop (the ISSUE's baseline),
``speedup_vs_per_step`` = superstep vs the improved per-step engine.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import DiLoCoConfig, OptimizerConfig, TrainConfig, get_config
from repro.core import streaming
from repro.core.diloco import make_trainer
from repro.core.superstep import SuperstepEngine
from repro.data import SyntheticLM
from repro.models import build_model

# the acceptance grid: DP vs DiLoCo vs int8 vs int4 vs streaming, M=4, H=20
# (int4 goes through the sync-strategy registry — the path a user-registered
# strategy takes — so `make bench-smoke` exercises it on every CI run)
MODES = {
    "dp": dict(num_replicas=1, data_parallel=True),
    "diloco": dict(num_replicas=4),
    "diloco_int8": dict(num_replicas=4, sync="int8"),
    "diloco_int4": dict(num_replicas=4, sync="int4"),
    "streaming": dict(num_replicas=4, sync="streaming:fragments=4"),
}


def build(arch, mode, steps, batch_tokens, seq_len, sync_every):
    cfg = get_config(arch).replace(max_seq_len=seq_len)
    model = build_model(cfg)
    dkw = dict(sync_every=sync_every)
    dkw.update(MODES[mode])
    trainer = make_trainer(
        model, DiLoCoConfig(**dkw),
        OptimizerConfig(peak_lr=1e-3, warmup_steps=10),
        TrainConfig(global_batch_tokens=batch_tokens, seq_len=seq_len, steps=steps),
    )
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq_len)
    return trainer, data


def _best_of(run_window, state, base, steps, windows):
    """Warmup already done; returns (best steps/sec, final state)."""
    best = 0.0
    for w in range(windows):
        t0 = time.perf_counter()
        state = run_window(state, base + w * steps, steps)
        best = max(best, steps / (time.perf_counter() - t0))
    return best, state


def time_loop(trainer, data, steps, seqs, windows, *, donate):
    """Per-step loops: ``donate=False`` is the seed baseline (state copied
    every call, eager streaming sync); ``donate=True`` is --engine per-step."""
    strat = trainer.sync
    H, P = trainer.dcfg.sync_every, strat.num_fragments
    if donate:
        inner, outer = trainer.jit_inner_step(), trainer.jit_outer_sync()
    else:
        inner, outer = jax.jit(trainer.inner_step), jax.jit(trainer.outer_sync)
    frag = (streaming.FragmentSync(trainer, donate=donate)
            if P > 0 and strat.uses_outer_opt else None)

    def window(state, base, n):
        for t in range(base, base + n):
            batch = data.global_batch(t, trainer.M, seqs)
            state, metrics = inner(state, batch)
            if strat.uses_outer_opt:
                if frag is not None:
                    for p in strat.fragments_due(t + 1, H):
                        # seed behavior: eager per-leaf sync, Python flatten
                        # per call; engine behavior: cached jitted executable
                        state = frag.jitted(p)(state) if donate else frag.apply(state, p)
                elif (t + 1) % H == 0:
                    state = outer(state)
            _ = float(metrics["loss"])  # the per-step host sync
        return state

    state = trainer.init_state(jax.random.PRNGKey(0))
    state = window(state, 0, H)  # warmup: compile + one full round
    return _best_of(window, state, H, steps, windows)[0]


def time_superstep(trainer, data, steps, seqs, windows):
    """The engine: one compiled round per dispatch, one host sync per round.
    unroll=4 is the tuned setting for ladder-scale models (fewer while-loop
    carry round-trips at modest compile cost)."""
    engine = SuperstepEngine(trainer, data, seqs, unroll=4)
    H = engine.chunk

    def window(state, base, n):
        state, mets = engine.run(state, base + n, start=base)
        _ = float(np.asarray(mets["loss"])[-1])
        return state

    state = trainer.init_state(jax.random.PRNGKey(0))
    state = window(state, 0, H)  # warmup: compile one round
    return _best_of(window, state, H, steps, windows)[0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-t1")
    ap.add_argument("--steps", type=int, default=60,
                    help="timed steps per window (beyond one warmup round)")
    ap.add_argument("--windows", type=int, default=5,
                    help="timed windows per engine; best is reported")
    ap.add_argument("--sync-every", type=int, default=20)
    ap.add_argument("--batch-tokens", type=int, default=32,
                    help="small by default: the bench targets the "
                         "overhead-dominated regime (see module docstring)")
    ap.add_argument("--seq-len", type=int, default=8)
    ap.add_argument("--modes", default="",
                    help="comma-separated subset of " + ",".join(MODES))
    ap.add_argument("--out", default="results/BENCH_engine.json")
    args = ap.parse_args()

    modes = [m for m in args.modes.split(",") if m] or list(MODES)
    rows = []
    print(f"{'mode':13s} {'seed sps':>9s} {'per-step sps':>13s} "
          f"{'superstep sps':>14s} {'vs seed':>8s} {'vs per-step':>12s}")
    for mode in modes:
        mk = lambda: build(args.arch, mode, args.steps, args.batch_tokens,
                           args.seq_len, args.sync_every)
        trainer, data = mk()
        seqs = max(1, args.batch_tokens // args.seq_len // trainer.M)
        sps_seed = time_loop(trainer, data, args.steps, seqs, args.windows, donate=False)
        trainer, data = mk()  # fresh jit caches per engine
        sps_loop = time_loop(trainer, data, args.steps, seqs, args.windows, donate=True)
        trainer, data = mk()
        sps_engine = time_superstep(trainer, data, args.steps, seqs, args.windows)
        row = {
            "mode": mode,
            "seed_loop_steps_per_s": sps_seed,
            "per_step_steps_per_s": sps_loop,
            "superstep_steps_per_s": sps_engine,
            "speedup_vs_seed": sps_engine / sps_seed,
            "speedup_vs_per_step": sps_engine / sps_loop,
        }
        rows.append(row)
        print(f"{mode:13s} {sps_seed:9.2f} {sps_loop:13.2f} {sps_engine:14.2f} "
              f"{row['speedup_vs_seed']:7.2f}x {row['speedup_vs_per_step']:11.2f}x")

    out = {
        "arch": args.arch,
        "sync_every": args.sync_every,
        "batch_tokens": args.batch_tokens,
        "seq_len": args.seq_len,
        "timed_steps": args.steps,
        "windows": args.windows,
        "device": jax.devices()[0].platform,
        "results": rows,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
